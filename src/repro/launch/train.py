"""Training driver: real, runnable end-to-end (CPU-scale configs), with the
full production feature set — mesh + named shardings, microbatched grad
accumulation, remat, checkpoint/restart (atomic, resumable), async saves,
and deterministic restart-safe data.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --steps 50 --mesh 1x1 --ckpt-dir /tmp/ckpt

On a real TPU pod the same driver runs with --mesh 16x16; nothing in the
loop is CPU-specific. Straggler/fault posture: the step is synchronous SPMD
(stragglers surface as step-time tail, mitigated by the checkpoint/restart
path and the elastic re-mesh in repro.distributed.elastic); node failure =>
restart from latest complete checkpoint on the surviving divisor mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import reduced
from ..configs.registry_configs import ALL_ARCHS
from ..data.pipeline import make_pipeline
from ..distributed import checkpoint as ckpt
from ..models.registry import get_adapter
from ..train.train_step import TrainState, make_train_step, train_state_init
from .mesh import make_mesh
from ..compat import set_mesh, tree_map


def build(arch: str, use_reduced: bool, mesh_shape: tuple, seq_len: int,
          global_batch: int, microbatches: int, lr: float):
    cfg = ALL_ARCHS[arch]
    if use_reduced:
        cfg = reduced(cfg)
    adapter = get_adapter(cfg)
    tp = mesh_shape[-1]
    mesh = make_mesh(mesh_shape, ("data", "model")[-len(mesh_shape):]
                     if len(mesh_shape) == 2 else ("data",))

    def loss_fn(params, batch):
        return adapter.loss(params, batch, remat=True)

    step = make_train_step(loss_fn, microbatches=microbatches, lr=lr)
    return cfg, adapter, mesh, step, tp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=sorted(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL, e.g. 16x16 on a pod, 1x1 on CPU")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    cfg, adapter, mesh, step, tp = build(
        args.arch, args.reduced, mesh_shape, args.seq_len,
        args.global_batch, args.microbatches, args.lr)

    pipe = make_pipeline(cfg.vocab, args.seq_len, args.global_batch,
                         seed=args.seed)

    with set_mesh(mesh):
        params = adapter.init(jax.random.PRNGKey(args.seed), tp=tp)
        state = train_state_init(params)

        start_step = 0
        if args.ckpt_dir:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                state = ckpt.restore(args.ckpt_dir, latest, state)
                start_step = latest + 1
                print(f"[train] resumed from step {latest}")

        jstep = jax.jit(step, donate_argnums=(0,))
        saver = ckpt.AsyncCheckpointer() if args.async_ckpt else None

        losses = []
        t0 = time.time()
        for i in range(start_step, start_step + args.steps):
            batch = tree_map(jnp.asarray, pipe.batch_at(i))
            state, metrics = jstep(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if i % 5 == 0 or i == start_step + args.steps - 1:
                print(f"[train] step {i} loss {loss:.4f} "
                      f"({(time.time()-t0):.1f}s)", flush=True)
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                if saver:
                    saver.save(args.ckpt_dir, i, state)
                else:
                    ckpt.save(args.ckpt_dir, i, state)
        if saver:
            saver.close()

    if len(losses) >= 10:
        first = np.mean(losses[:3])
        last = np.mean(losses[-3:])
        print(f"[train] loss {first:.3f} -> {last:.3f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
