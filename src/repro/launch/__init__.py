# Launch layer: production mesh, multi-pod dry-run, roofline analysis, and
# the train/serve drivers. Import of this package never touches jax device
# state (mesh construction is behind functions).
