import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (brief: MULTI-POD DRY-RUN).

For every (architecture x input shape) cell, on the single-pod 16x16 mesh
AND the multi-pod 2x16x16 mesh:

    with mesh:
        lowered  = jax.jit(step).lower(*abstract_args)
        compiled = lowered.compile()
        compiled.memory_analysis()     # proves the cell fits per-chip HBM
        compiled.cost_analysis()       # FLOPs / bytes for the roofline

plus the collective-bytes parse of the optimized HLO. Results accumulate in
results/dryrun.json (resumable: finished cells are skipped unless --force).

Usage:
    python -m repro.launch.dryrun                         # everything
    python -m repro.launch.dryrun --arch qwen2-7b         # one arch
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k \
        --mesh multi                                       # one cell
"""
import argparse
import json
import time
import traceback

import jax

from ..compat import set_mesh
from ..configs.registry_configs import ALL_ARCHS
from ..configs.shapes import SHAPES
from .hlo_analysis import analyze_hlo, xla_cost_analysis
from .mesh import make_production_mesh
from .plans import cell_supported, make_cell
from .roofline import Roofline, model_bytes, model_flops

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results")


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             opt_flags: dict | None = None) -> dict:
    """Lower + compile one cell; returns the record for dryrun.json."""
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.devices.size
    shape = SHAPES[shape_name]
    cfg = ALL_ARCHS[arch]

    ok, reason = cell_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "SKIP", "reason": reason}

    t0 = time.time()
    # compat.set_mesh resolves to jax.set_mesh where available (not a bare
    # `with mesh:`) — on those versions only set_mesh installs the abstract
    # mesh that with_sharding_constraint needs during tracing; under a bare
    # Mesh context every shard_hint in the model silently no-ops (measured:
    # llama-90b train activations lost their batch sharding, 1.7 TB/chip).
    with set_mesh(mesh):
        plan = make_cell(arch, shape_name, mesh, **(opt_flags or {}))
        jitted = jax.jit(plan.fn, donate_argnums=plan.donate)
        lowered = jitted.lower(*plan.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = xla_cost_analysis(compiled)
        hlo = compiled.as_text()
    st = analyze_hlo(hlo)

    mem_gb = (mem.argument_size_in_bytes + mem.output_size_in_bytes
              + mem.temp_size_in_bytes) / 1e9 if mem else float("nan")
    args_gb = mem.argument_size_in_bytes / 1e9 if mem else float("nan")

    rf = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_kind,
        flops_per_chip=st.flops, bytes_per_chip=st.bytes_accessed,
        coll_bytes_per_chip=st.collective_bytes,
        model_flops_total=model_flops(cfg, shape),
        model_bytes_total=model_bytes(cfg, shape),
        n_chips=n_chips, coll_by_kind=dict(st.coll_by_kind),
        mem_per_chip_gb=mem_gb)

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "OK",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "mem_per_chip_gb": round(mem_gb, 3),
        "args_per_chip_gb": round(args_gb, 3),
        "flops_per_chip": st.flops,
        "bytes_per_chip": st.bytes_accessed,
        "coll_bytes_per_chip": st.collective_bytes,
        "coll_by_kind": dict(st.coll_by_kind),
        "n_collectives": st.n_collectives,
        "cost_analysis_flops": float(cost.get("flops", 0.0)),
        "cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
        "roofline": rf.row(),
    }


def _load(path: str) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def _key(a: str, s: str, m: str) -> str:
    return f"{a}|{s}|{m}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape (default all)")
    ap.add_argument("--mesh", default=None, choices=("single", "multi"),
                    help="default: both")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    out_path = args.out or os.path.join(RESULTS, "dryrun.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    results = _load(out_path)

    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                k = _key(arch, shape, mesh_kind)
                if not args.force and results.get(k, {}).get("status") in (
                        "OK", "SKIP"):
                    print(f"[cached] {k}: {results[k]['status']}")
                    continue
                print(f"[run] {k} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_kind)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    failures += 1
                results[k] = rec
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
                status = rec["status"]
                extra = (f" mem={rec.get('mem_per_chip_gb')}GB "
                         f"compile={rec.get('compile_s')}s"
                         if status == "OK" else
                         rec.get("reason") or rec.get("error", ""))
                print(f"  -> {status} {extra}", flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "OK")
    n_skip = sum(1 for r in results.values() if r["status"] == "SKIP")
    n_fail = sum(1 for r in results.values() if r["status"] == "FAIL")
    print(f"\ndry-run summary: {n_ok} OK / {n_skip} SKIP / {n_fail} FAIL "
          f"(of {len(results)} recorded)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
