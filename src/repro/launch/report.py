"""Render the roofline table (EXPERIMENTS.md §Roofline) from
results/dryrun.json.

    PYTHONPATH=src python -m repro.launch.report [results/dryrun.json]
"""
from __future__ import annotations

import json
import os
import sys

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def one_liner(rec: dict) -> str:
    """One sentence on what would move the dominant term down."""
    rf = rec.get("roofline", {})
    b = rf.get("bottleneck")
    arch, shape = rec["arch"], rec["shape"]
    if rec["status"] != "OK":
        return rec.get("reason", "")
    if b == "memory":
        ratio = rf.get("t_memory_ms", 0) / max(rf.get("t_ideal_ms", 1e-9),
                                               1e-9)
        if "decode" in shape or "long" in shape:
            return (f"memory-bound at {ratio:.0f}x ideal bytes: shrink "
                    "cache round-trips (scan ys double-buffering, cache "
                    "dtype/layout) and stream KV at row granularity")
        return (f"memory-bound at {ratio:.0f}x ideal bytes: fuse "
                "norm/rope/residual traffic and keep activations sharded")
    if b == "compute":
        return ("compute-bound: raise MXU utilization (padding waste, "
                "remat recompute) or shard more of the contraction")
    return ("collective-bound: overlap all-reduce with microbatch "
            "compute, compress cross-pod gradients, reorder "
            "gather/scatter around attention")


def render(results: dict) -> str:
    rows = []
    hdr = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) "
           "| bound | MODEL_FLOPs | useful | roofline frac | mem GB/chip |")
    sep = "|" + "---|" * 11
    rows.append(hdr)
    rows.append(sep)
    recs = sorted(results.values(),
                  key=lambda r: (r["arch"], ORDER.index(r["shape"]),
                                 r["mesh"]))
    for r in recs:
        if r["status"] == "SKIP":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"SKIP — {r['reason'][:60]}… |" + " |" * 7)
            continue
        if r["status"] != "OK":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"FAIL |" + " |" * 7)
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['t_compute_ms']:.2f} | {rf['t_memory_ms']:.2f} "
            f"| {rf['t_collective_ms']:.3f} | {rf['bottleneck']} "
            f"| {rf['model_flops']:.2e} | {rf['useful_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.3f} "
            f"| {r['mem_per_chip_gb']:.2f} |")
    return "\n".join(rows)


def notes(results: dict) -> str:
    out = []
    for r in sorted(results.values(), key=lambda r: r["arch"]):
        if r["status"] == "OK" and r["mesh"] == "single":
            out.append(f"* **{r['arch']} x {r['shape']}** — "
                       f"{one_liner(r)}")
    return "\n".join(out)


def main(argv=None) -> int:
    path = (argv or sys.argv[1:] or
            [os.path.join("results", "dryrun.json")])[0]
    with open(path) as f:
        results = json.load(f)
    print(render(results))
    print()
    print(notes(results))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
