"""Sharding plans: abstract (ShapeDtypeStruct) arguments with NamedShardings
for every (arch x input-shape x mesh) cell.

The model zoo declares *intent* as named-axis spec tuples
(``param_specs`` / ``cache_specs`` / ``state_specs``); this module makes the
intent concrete for a given mesh and shape:

* axes not on the mesh are dropped (single-pod vs multi-pod),
* axes whose size does not divide the dimension are dropped (e.g. rwkv6's
  40 heads on a 16-way model axis, batch=1 on the DP axes),
* everything is returned as jax.ShapeDtypeStruct with .sharding attached,
  so ``jit(f).lower(*args)`` needs no separate in_shardings.

No device allocation happens anywhere in this module.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import mesh_axis_sizes, tree_map
from ..configs.registry_configs import ALL_ARCHS
from ..configs.shapes import SHAPES, InputShape
from ..distributed.sharding import activation_sharding
from ..models.registry import ModelAdapter, get_adapter
from ..train.optimizer import AdamWState
from ..train.train_step import TrainState, make_train_step

TP = 16   # model-axis width of the production mesh


# ---------------------------------------------------------------------------
# Spec concretization
# ---------------------------------------------------------------------------

def _axis_size(mesh, name) -> int:
    return mesh_axis_sizes(mesh)[name]


def concretize_entry(entry, dim: int, mesh) -> Any:
    """One PartitionSpec entry -> entry valid for `dim` on `mesh`."""
    names = tuple(mesh.axis_names)
    if entry is None:
        return None
    axes = [a for a in (entry if isinstance(entry, (tuple, list)) else
                        (entry,)) if a in names]
    # Drop axes (outermost first) until the product divides the dim.
    while axes:
        prod = 1
        for a in axes:
            prod *= _axis_size(mesh, a)
        if dim % prod == 0:
            break
        axes.pop(0)
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def concretize_spec(spec: tuple, shape: tuple, mesh) -> P:
    spec = tuple(spec) + (None,) * (len(shape) - len(spec))
    used: set = set()
    entries = []
    for e, d in zip(spec, shape):
        c = concretize_entry(e, d, mesh)
        # An axis name may appear at most once in a PartitionSpec.
        if c is not None:
            cs = c if isinstance(c, tuple) else (c,)
            cs = tuple(a for a in cs if a not in used)
            used.update(cs)
            c = cs if len(cs) > 1 else (cs[0] if cs else None)
        entries.append(c)
    return P(*entries)


def with_sharding(structs, specs, mesh):
    """Attach NamedShardings to a pytree of ShapeDtypeStructs."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, tuple, list, type(None))) for e in x)

    def one(s, spec):
        p = concretize_spec(tuple(spec), s.shape, mesh)
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, p))

    return tree_map(one, structs, specs, is_leaf=lambda x: is_spec(x))


def replicated(structs, mesh):
    return tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, P())), structs)


# ---------------------------------------------------------------------------
# Abstract model state
# ---------------------------------------------------------------------------

def abstract_params(adapter: ModelAdapter, mesh, fsdp: Optional[str] = "data"):
    """ShapeDtypeStructs for the parameters, sharded per param_specs."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    structs = jax.eval_shape(lambda k: adapter.init(k, tp=TP), key)
    specs = adapter.param_specs(fsdp=fsdp, tp=TP)
    return with_sharding(structs, specs, mesh), specs


def abstract_opt_state(params_structs, specs, mesh):
    """AdamW moments shard exactly like their parameters (fp32)."""
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                         sharding=s.sharding)
    mu = tree_map(f32, params_structs)
    nu = tree_map(f32, params_structs)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return AdamWState(step=step, mu=mu, nu=nu)


def batch_structs(adapter: ModelAdapter, shape: InputShape, mesh) -> dict:
    """Sharded input batch stand-ins (brief: input_specs())."""
    structs = adapter.input_structs(shape.seq_len, shape.global_batch,
                                    shape.kind)
    out = {}
    for name, s in structs.items():
        spec = (("pod", "data"),) + (None,) * (len(s.shape) - 1)
        p = concretize_spec(spec, s.shape, mesh)
        out[name] = jax.ShapeDtypeStruct(s.shape, s.dtype,
                                         sharding=NamedSharding(mesh, p))
    return out


def abstract_cache(adapter: ModelAdapter, shape: InputShape, mesh):
    """Decode-state stand-ins sharded per state_specs."""
    cfg = adapter.cfg
    seq = shape.seq_len
    structs = jax.eval_shape(
        lambda: adapter.init_decode_state(shape.global_batch, seq, tp=TP))
    specs = adapter.state_specs()
    return with_sharding(structs, specs, mesh)


# ---------------------------------------------------------------------------
# Step functions (what the dry-run lowers)
# ---------------------------------------------------------------------------

@dataclass
class CellPlan:
    arch: str
    shape: InputShape
    fn: Callable              # jit-able
    args: tuple               # abstract args (ShapeDtypeStruct pytrees)
    kind: str                 # "train" | "prefill" | "decode"
    donate: tuple = ()


def train_memory_plan(cfg, shape: InputShape, mesh,
                      act_budget_gb: float = 5.0) -> tuple[int, bool]:
    """(microbatches, seq_shard): gradient-accumulation factor so the
    per-microbatch saved activations (one residual per layer under remat)
    fit the HBM budget; if even one sample per microbatch exceeds it,
    additionally shard the residual stream's sequence dim over the model
    axis (sequence parallelism). Production practice: global batch is set
    by the recipe; microbatching + SP are the memory knobs."""
    sizes = mesh_axis_sizes(mesh)
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    tp = sizes.get("model", 1)
    b_local = max(1, shape.global_batch // dp)
    n_layers = cfg.n_layers + getattr(cfg, "encoder_layers", 0)
    act_gb = (b_local * shape.seq_len * cfg.d_model * 2 * n_layers) / 1e9
    mb = 1
    while act_gb / mb > act_budget_gb and mb < b_local:
        mb *= 2
    while b_local % mb:
        mb *= 2
    mb = min(mb, b_local)
    # Sequence parallelism measured counterproductive as a *default* once
    # every block (incl. cross-attention) is rematerialized — saved carries
    # no longer dominate and SP's gather/scatter buffers offset its savings
    # (llama-90b train: 8.0 GB temp with or without SP; EXPERIMENTS.md
    # §Perf). Kept as an explicit knob for the hillclimb.
    seq_shard = False
    return mb, seq_shard


def auto_microbatches(cfg, shape: InputShape, mesh,
                      act_budget_gb: float = 5.0) -> int:
    return train_memory_plan(cfg, shape, mesh, act_budget_gb)[0]


def make_train_cell(arch: str, shape: InputShape, mesh, *,
                    remat: bool = True, fsdp: bool = True,
                    microbatches: int | None = None,
                    seq_shard: bool | None = None,
                    pin_grads: bool = True) -> CellPlan:
    adapter = get_adapter(arch)
    p_structs, specs = abstract_params(adapter, mesh,
                                       fsdp="data" if fsdp else None)
    opt = abstract_opt_state(p_structs, specs, mesh)
    state = TrainState(params=p_structs, opt=opt)
    batch = batch_structs(adapter, shape, mesh)
    auto_mb, auto_sp = train_memory_plan(adapter.cfg, shape, mesh)
    if microbatches is None:
        microbatches = auto_mb
    if seq_shard is None:
        seq_shard = auto_sp

    loss_fn = partial(_adapter_loss, adapter, remat)
    step = make_train_step(loss_fn, microbatches=microbatches,
                           param_specs=specs if pin_grads else None)
    if seq_shard:
        inner = step

        def step(state, batch):  # noqa: F811 — SP-wrapped variant
            with activation_sharding("model"):
                return inner(state, batch)

    return CellPlan(arch, shape, step, (state, batch), "train",
                    donate=(0,))


def _adapter_loss(adapter, remat, params, batch):
    return adapter.loss(params, batch, remat=remat)


def auto_fsdp_serving(cfg, mesh, budget_gb: float = 4.0) -> bool:
    """Serving keeps weights TP-sharded for latency; when a model's
    TP-sharded weights alone exceed `budget_gb`/chip, FSDP-shard them over
    `data` too and pay the per-layer gather. Measured (EXPERIMENTS.md
    §Perf B.2): llama-90b decode −37.6 GB/chip and −54 ms memory for
    +8.6 ms collective; phi3.5-moe decode 22.0 -> 5.6 GB/chip."""
    tp = mesh_axis_sizes(mesh).get("model", 1)
    return (cfg.n_params() * 2 / tp) / 1e9 > budget_gb


def make_prefill_cell(arch: str, shape: InputShape, mesh,
                      fsdp: bool | None = None) -> CellPlan:
    adapter = get_adapter(arch)
    if fsdp is None:
        fsdp = auto_fsdp_serving(adapter.cfg, mesh)
    p_structs, _ = abstract_params(adapter, mesh,
                                   fsdp="data" if fsdp else None)
    batch = batch_structs(adapter, shape, mesh)

    def prefill(params, batch):
        return adapter.forward(params, batch, remat=True)

    return CellPlan(arch, shape, prefill, (p_structs, batch), "prefill")


def make_decode_cell(arch: str, shape: InputShape, mesh,
                     fsdp: bool | None = None) -> CellPlan:
    adapter = get_adapter(arch)
    if fsdp is None:
        fsdp = auto_fsdp_serving(adapter.cfg, mesh)
    p_structs, _ = abstract_params(adapter, mesh,
                                   fsdp="data" if fsdp else None)
    batch = batch_structs(adapter, shape, mesh)
    cache = abstract_cache(adapter, shape, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))

    def serve_step(params, batch, cache, pos):
        return adapter.decode(params, batch, cache, pos)

    return CellPlan(arch, shape, serve_step, (p_structs, batch, cache, pos),
                    "decode", donate=(2,))


def make_cell(arch: str, shape_name: str, mesh, **kw) -> CellPlan:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return make_train_cell(arch, shape, mesh, **kw)
    if shape.kind == "prefill":
        return make_prefill_cell(arch, shape, mesh, **kw)
    return make_decode_cell(arch, shape, mesh, **kw)


def cell_supported(arch: str, shape_name: str) -> tuple[bool, str]:
    shape = SHAPES[shape_name]
    adapter = get_adapter(arch)
    return adapter.supports(shape.kind, shape.seq_len)


ALL_CELLS = [(a, s) for a in ALL_ARCHS for s in SHAPES]
