"""Serving driver: continuous-batching decode over the row-paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 12 --slots 4

Iteration-level scheduling (Orca-style): new requests join the running
batch at token boundaries; the jit'd decode step is shape-stable over a
fixed slot array. Each slot owns a contiguous region of the shared KV
cache; the serve layer accounts pages at 4 KB DRAM-row granularity
(repro.serve.kv_cache) — the software contract of the RoMe interface.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import reduced
from ..configs.registry_configs import ALL_ARCHS
from ..models.registry import get_adapter
from ..serve.batching import ContinuousBatcher, Request
from ..serve.kv_cache import ROW_BYTES
from .mesh import make_mesh
from ..compat import set_mesh


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=sorted(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ALL_ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    adapter = get_adapter(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))

    rng = np.random.default_rng(args.seed)
    batcher = ContinuousBatcher(args.slots)
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=(args.prompt_len,),
                              dtype=np.int32)
        batcher.submit(Request(rid, prompt,
                               max_new_tokens=args.max_new))

    with set_mesh(mesh):
        params = adapter.init(jax.random.PRNGKey(args.seed), tp=1)
        cache = adapter.init_decode_state(args.slots, args.max_seq)

        @jax.jit
        def decode_step(params, tokens, cache, pos):
            logits, cache = adapter.decode(params, {"tokens": tokens},
                                           cache, pos)
            return greedy_sample(logits), cache

        # Slot state: current token and per-slot position.
        cur = np.zeros((args.slots, 1), np.int32)
        pos = 0
        t0 = time.time()
        tokens_out = 0
        while not batcher.idle():
            admitted = batcher.schedule()
            for slot, req in admitted:
                # Prefill-as-decode: feed prompt tokens one at a time into
                # the slot (a production server would run a prefill kernel;
                # the cache/page accounting is identical).
                cur[slot, 0] = req.prompt[0]
            step_tokens, cache = decode_step(
                params, jnp.asarray(cur), cache,
                jnp.asarray(pos, jnp.int32))
            out = np.asarray(step_tokens)
            finished = batcher.record_tokens(out)
            for slot in range(args.slots):
                if batcher.active[slot] is not None:
                    cur[slot, 0] = out[slot]
            tokens_out += sum(1 for r in batcher.active if r is not None)
            pos = min(pos + 1, args.max_seq - 1)
            for req in finished:
                print(f"[serve] request {req.rid} done "
                      f"({len(req.out_tokens)} tokens)")
        dt = time.time() - t0

    print(f"[serve] {len(batcher.completed)} requests, "
          f"{batcher.steps} decode steps, occupancy "
          f"{batcher.occupancy:.2f}, {tokens_out/max(dt,1e-9):.1f} tok/s")
    kv_bytes_tok = 2 * cfg.n_layers * cfg.n_kv_heads \
        * cfg.resolved_head_dim * 2
    print(f"[serve] KV bytes/token/all-layers = {kv_bytes_tok} "
          f"({kv_bytes_tok/ROW_BYTES:.2f} DRAM rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
