from .accelerator import (N_ACCELERATORS, AcceleratorSpec, paper_accelerator,
                          scaled_accelerator, tpu_v5e)
from .tpot import (StepTime, decode_stream, max_batch, prefill_ns,
                   step_time, stream_mem_ns, tpot_ns, xval_decode_stream)

__all__ = ["N_ACCELERATORS", "AcceleratorSpec", "paper_accelerator",
           "scaled_accelerator", "tpu_v5e", "StepTime", "max_batch",
           "prefill_ns", "step_time", "tpot_ns", "decode_stream",
           "stream_mem_ns", "xval_decode_stream"]
