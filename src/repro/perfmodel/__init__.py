from .accelerator import (N_ACCELERATORS, AcceleratorSpec, paper_accelerator,
                          tpu_v5e)
from .tpot import StepTime, max_batch, prefill_ns, step_time, tpot_ns

__all__ = ["N_ACCELERATORS", "AcceleratorSpec", "paper_accelerator",
           "tpu_v5e", "StepTime", "max_batch", "prefill_ns", "step_time",
           "tpot_ns"]
