"""Channel load-balance ratio (paper Fig 13).

LBR quantifies how uniformly a step's memory extents spread over the
memory channels at RoMe's 4 KB striping granularity, normalized to the
HBM4 baseline (whose 32 B stripes make LBR ~= 1 for any realistic extent).
Computed per layer kind (attention vs FFN) from the same layer-op traces
that drive the TPOT model, so Fig 12 and Fig 13 share one source of truth.
"""
from __future__ import annotations

from ..configs.paper_workloads import PaperWorkload
from ..core.address_map import load_balance_ratio, make_address_map
from ..core.timing import hbm4_config, rome_config
from ..trace.layergraph import decode_ops


def lbr_by_kind(w: PaperWorkload, batch: int, seq_len: int = 8192,
                n_devices: int = 8, n_cubes: int = 8) -> dict:
    """{'attn': LBR, 'ffn': LBR} for RoMe, normalized to HBM4."""
    ops = decode_ops(w, batch, seq_len, n_devices)
    amap_r = make_address_map(rome_config(), n_cubes)
    amap_h = make_address_map(hbm4_config(), n_cubes)
    out = {}
    for kind in ("attn", "ffn"):
        k_ops = [op for op in ops if op.kind == kind and op.extents]
        if not k_ops:
            out[kind] = 1.0
            continue
        # Byte-weighted mean over the kind's ops; normalize to baseline.
        def weighted(amap):
            num = den = 0.0
            for op in k_ops:
                lbr = load_balance_ratio(amap, op.extents)
                num += lbr * op.read_bytes
                den += op.read_bytes
            return num / den if den else 1.0
        out[kind] = weighted(amap_r) / max(weighted(amap_h), 1e-9)
    return out


def lbr_sweep(w: PaperWorkload, batches=(1, 4, 16, 64, 256),
              seq_len: int = 8192) -> dict:
    return {b: lbr_by_kind(w, b, seq_len) for b in batches}
