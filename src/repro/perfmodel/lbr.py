"""Channel load-balance ratio (paper Fig 13).

LBR quantifies how uniformly a step's memory extents spread over the
memory channels at RoMe's 4 KB striping granularity, normalized to the
HBM4 baseline (whose 32 B stripes make LBR ~= 1 for any realistic extent).
Computed per layer kind (attention vs FFN) from the same layer-op traces
that drive the TPOT model and the unified extent streams, so Fig 12,
Fig 13, and the SystemSim workloads share one source of truth. Writes
carry real row-aligned addresses (KV append / activation stores), so the
write path can be included in the census (``include_writes``).
"""
from __future__ import annotations

from ..configs.paper_workloads import PaperWorkload
from ..core.address_map import load_balance_ratio, make_address_map
from ..core.timing import hbm4_config, rome_config
from ..trace.layergraph import decode_ops


def lbr_by_kind(w: PaperWorkload, batch: int, seq_len: int = 8192,
                n_devices: int = 8, n_cubes: int = 8,
                include_writes: bool = False) -> dict:
    """{'attn': LBR, 'ffn': LBR} for RoMe, normalized to HBM4.

    ``include_writes`` folds each op's row-aligned write extents into its
    extent set (byte-weighted alongside the reads).
    """
    ops = decode_ops(w, batch, seq_len, n_devices)
    amap_r = make_address_map(rome_config(), n_cubes)
    amap_h = make_address_map(hbm4_config(), n_cubes)
    out = {}
    for kind in ("attn", "ffn"):
        k_ops = [op for op in ops if op.kind == kind and op.extents]
        if not k_ops:
            out[kind] = 1.0
            continue
        # Byte-weighted mean over the kind's ops; normalize to baseline.
        def weighted(amap):
            num = den = 0.0
            for op in k_ops:
                extents = list(op.extents)
                nbytes = op.read_bytes
                if include_writes and op.write_extents:
                    extents += list(op.write_extents)
                    nbytes += op.write_bytes
                lbr = load_balance_ratio(amap, extents)
                num += lbr * nbytes
                den += nbytes
            return num / den if den else 1.0
        out[kind] = weighted(amap_r) / max(weighted(amap_h), 1e-9)
    return out


def lbr_sweep(w: PaperWorkload, batches=(1, 4, 16, 64, 256),
              seq_len: int = 8192, include_writes: bool = False) -> dict:
    return {b: lbr_by_kind(w, b, seq_len, include_writes=include_writes)
            for b in batches}
