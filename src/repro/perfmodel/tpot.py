"""TPOT model (paper Fig 12): decode/prefill step time under HBM4 vs RoMe.

Per layer-op roofline: t_op = max(memory_time, compute_time) +
kernel overhead. memory_time divides the op's bytes by the *effective*
bandwidth: peak x calibrated channel efficiency x the op's load-balance
ratio (RoMe's 4 KB striping granularity; HBM4's 32 B granularity keeps
LBR ~= 1). Reads and writes both go through the LBR path (writes carry
real row-aligned extents from the layer-op allocator). The calibrated
efficiencies come from the cycle-level engine (repro.core.analytic), so
this model and the engine agree on overlapping regimes by construction.

The model also speaks the unified workload currency: ``decode_stream``
builds the timed :class:`repro.workloads.ExtentStream` for a decode step
and ``stream_mem_ns`` computes the step's memory time from any such
stream — the same object :class:`repro.core.system_sim.SystemSim`
simulates, which is what the TPOT-vs-makespan cross-validation in
``benchmarks/engine_xval.py`` rides on.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..configs.paper_workloads import PaperWorkload
from ..core.address_map import AddressMap, load_balance_ratio
from ..core.analytic import calibrate
from ..trace.layergraph import LayerOp, decode_ops, prefill_ops
from .accelerator import AcceleratorSpec, N_ACCELERATORS


@dataclass
class StepTime:
    total_ns: float
    mem_ns: float
    comp_ns: float
    per_kind_ns: dict
    lbr_per_kind: dict


def _mem_ns(read_extents: list, read_bytes: int,
            write_extents: list, write_bytes: int,
            peak: float, amap: AddressMap,
            read_eff: float, write_eff: float) -> tuple[float, float]:
    """(mem_ns, read_lbr): shared read+write memory-time formula.

    Both kinds divide their bytes by LBR-degraded effective bandwidth;
    writes without addresses (legacy prefill scaling) fall back to LBR=1.
    """
    lbr = load_balance_ratio(amap, read_extents) if read_extents else 1.0
    read_ns = (read_bytes / lbr) / (peak * read_eff) if read_bytes else 0.0
    lbr_w = load_balance_ratio(amap, write_extents) if write_extents else 1.0
    write_ns = ((write_bytes / lbr_w) / (peak * write_eff)
                if write_bytes else 0.0)
    return read_ns + write_ns, lbr


def op_times_ns(op: LayerOp, acc: AcceleratorSpec, amap: AddressMap,
                read_eff: float, write_eff: float) -> tuple[float, float, float]:
    """(mem_ns, comp_ns, lbr) for one op."""
    mem_ns, lbr = _mem_ns(op.extents, op.read_bytes,
                          op.write_extents, op.write_bytes,
                          acc.peak_bw_gbps, amap, read_eff, write_eff)
    comp_ns = op.flops / (acc.bf16_tflops * 1e3)   # TFLOPs -> ns
    return mem_ns, comp_ns, lbr


def step_time(ops: list[LayerOp], acc: AcceleratorSpec) -> StepTime:
    eff = calibrate(acc.mem_cfg)
    amap = acc.address_map()
    total = mem_total = comp_total = 0.0
    per_kind: dict = {}
    lbr_acc: dict = {}
    for op in ops:
        m, c, lbr = op_times_ns(op, acc, amap, eff.read_eff, eff.write_eff)
        t = max(m, c) + acc.kernel_overhead_ns
        total += t
        mem_total += m
        comp_total += c
        per_kind[op.kind] = per_kind.get(op.kind, 0.0) + t
        if op.kind in ("attn", "ffn"):
            b, ideal = lbr_acc.get(op.kind, (0.0, 0.0))
            lbr_acc[op.kind] = (b + op.read_bytes,
                                ideal + op.read_bytes / max(lbr, 1e-9))
    lbr_per_kind = {k: (b / ideal if ideal else 1.0)
                    for k, (b, ideal) in lbr_acc.items()}
    return StepTime(total, mem_total, comp_total, per_kind, lbr_per_kind)


# ---------------------------------------------------------------------------
# Stream-level API (unified workload currency)
# ---------------------------------------------------------------------------

def stream_mem_ns(stream, acc: AcceleratorSpec,
                  amap: AddressMap | None = None) -> float:
    """Step memory time of an :class:`repro.workloads.ExtentStream`.

    Records are grouped by ``stream_id`` (= issuing layer op); each
    group's reads and writes go through the same LBR-degraded effective
    bandwidth as :func:`op_times_ns`, and groups are summed — ops within
    one decode step are serialized by the layer dependency chain. For a
    stream built by :func:`repro.workloads.from_layer_ops` this equals
    ``step_time(ops, acc).mem_ns`` by construction (tests/test_workloads).
    """
    eff = calibrate(acc.mem_cfg)
    amap = amap or acc.address_map()
    peak = acc.peak_bw_gbps
    groups: dict[int, list] = {}
    for r in stream:
        groups.setdefault(r.stream_id, []).append(r)
    total = 0.0
    for recs in groups.values():
        reads = [(r.addr, r.nbytes) for r in recs if not r.is_write]
        writes = [(r.addr, r.nbytes) for r in recs if r.is_write]
        m, _ = _mem_ns(reads, sum(n for _, n in reads),
                       writes, sum(n for _, n in writes),
                       peak, amap, eff.read_eff, eff.write_eff)
        total += m
    return total


def decode_stream(w: PaperWorkload, acc: AcceleratorSpec, batch: int,
                  seq_len: int = 8192, n_devices: int = N_ACCELERATORS):
    """The timed decode-step :class:`~repro.workloads.ExtentStream` for one
    device — the exact workload object ``SystemSim.run`` simulates."""
    from ..workloads import from_layer_ops    # lazy: workloads imports tpot
    ops = decode_ops(w, batch, seq_len, n_devices)
    return from_layer_ops(ops, acc)


def xval_decode_stream(w: PaperWorkload, mem: str, n_channels: int = 2,
                       scale: float = 2 ** -11, n_ops: int = 8,
                       batch: int = 16, seq_len: int = 2048):
    """(stream, acc) for the TPOT-vs-makespan cross-validation regime.

    One canonical definition of the scaled decode slice — the first
    ``n_ops`` layer ops, byte-scaled so cycle-level simulation stays in
    seconds, on an ``n_channels``-wide system with §VI-A arithmetic
    intensity — shared by benchmarks/engine_xval.py, the tier-1 test,
    and examples/rome_vs_hbm4.py so they always validate the same
    regime. Simulate with ``SystemSim(acc.mem_cfg,
    n_channels=acc.n_channels).run(stream)`` and compare against
    :func:`stream_mem_ns`.
    """
    from ..workloads import from_layer_ops, scale_layer_ops
    from .accelerator import scaled_accelerator
    ops = scale_layer_ops(decode_ops(w, batch, seq_len)[:n_ops], scale)
    acc = scaled_accelerator(mem, n_channels=n_channels)
    return from_layer_ops(ops, acc), acc


# ---------------------------------------------------------------------------
# Public API (Fig 12 / Fig 13)
# ---------------------------------------------------------------------------

def tpot_ns(w: PaperWorkload, acc: AcceleratorSpec, batch: int,
            seq_len: int = 8192, n_devices: int = N_ACCELERATORS) -> StepTime:
    ops = decode_ops(w, batch, seq_len, n_devices)
    return step_time(ops, acc)


def prefill_ns(w: PaperWorkload, acc: AcceleratorSpec, batch: int,
               seq_len: int = 8192,
               n_devices: int = N_ACCELERATORS) -> StepTime:
    ops = prefill_ops(w, batch, seq_len, n_devices)
    return step_time(ops, acc)


def max_batch(w: PaperWorkload, seq_len: int = 8192,
              mem_capacity_gb: float = 256.0,
              n_devices: int = N_ACCELERATORS) -> int:
    """Largest power-of-two batch whose weights + KV fit system memory."""
    weights = _total_weight_bytes(w)
    cap = mem_capacity_gb * 1e9 * n_devices
    b = 1
    while True:
        kv = 2 * b * seq_len * w.kv_bytes_per_token_per_layer * w.n_layers
        if weights + kv > cap or b > 4096:
            return max(1, b // 2)
        b *= 2


def _total_weight_bytes(w: PaperWorkload) -> float:
    d = w.d_model
    attn = w.n_layers * (2 * d * (w.n_heads + w.n_kv_heads) * w.head_dim)
    if w.mla_kv_lora:
        attn = w.n_layers * (d * w.mla_q_lora
                             + w.mla_q_lora * w.n_heads * (w.head_dim + w.mla_rope_dim)
                             + d * (w.mla_kv_lora + w.mla_rope_dim)
                             + w.mla_kv_lora * w.n_heads * 2 * w.head_dim
                             + w.n_heads * w.head_dim * d)
    if w.is_moe:
        moe_layers = w.n_layers - w.n_dense_layers
        ffn = moe_layers * (w.n_experts + w.n_shared_experts) * 3 * d * w.d_ff
        ffn += w.n_dense_layers * 3 * d * w.dense_d_ff
    else:
        ffn = w.n_layers * 3 * d * w.d_ff
    return (attn + ffn + 2 * d * w.vocab) * w.bytes_per_param
