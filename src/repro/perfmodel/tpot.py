"""TPOT model (paper Fig 12): decode/prefill step time under HBM4 vs RoMe.

Per layer-op roofline: t_op = max(memory_time, compute_time) +
kernel overhead. memory_time divides the op's bytes by the *effective*
bandwidth: peak x calibrated channel efficiency x the op's load-balance
ratio (RoMe's 4 KB striping granularity; HBM4's 32 B granularity keeps
LBR ~= 1). The calibrated efficiencies come from the cycle-level engine
(repro.core.analytic), so this model and the engine agree on overlapping
regimes by construction.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..configs.paper_workloads import PaperWorkload
from ..core.address_map import AddressMap, load_balance_ratio, make_address_map
from ..core.analytic import calibrate
from ..trace.layergraph import LayerOp, decode_ops, prefill_ops
from .accelerator import AcceleratorSpec, N_ACCELERATORS


@dataclass
class StepTime:
    total_ns: float
    mem_ns: float
    comp_ns: float
    per_kind_ns: dict
    lbr_per_kind: dict


def op_times_ns(op: LayerOp, acc: AcceleratorSpec, amap: AddressMap,
                read_eff: float, write_eff: float) -> tuple[float, float, float]:
    """(mem_ns, comp_ns, lbr) for one op."""
    lbr = load_balance_ratio(amap, op.extents) if op.extents else 1.0
    peak = acc.peak_bw_gbps           # GB/s == B/ns
    read_ns = (op.read_bytes / lbr) / (peak * read_eff) if op.read_bytes else 0.0
    write_ns = op.write_bytes / (peak * write_eff) if op.write_bytes else 0.0
    comp_ns = op.flops / (acc.bf16_tflops * 1e3)   # TFLOPs -> ns
    return read_ns + write_ns, comp_ns, lbr


def step_time(ops: list[LayerOp], acc: AcceleratorSpec) -> StepTime:
    eff = calibrate(acc.mem_cfg)
    amap = make_address_map(acc.mem_cfg, acc.n_hbm_cubes)
    total = mem_total = comp_total = 0.0
    per_kind: dict = {}
    lbr_acc: dict = {}
    for op in ops:
        m, c, lbr = op_times_ns(op, acc, amap, eff.read_eff, eff.write_eff)
        t = max(m, c) + acc.kernel_overhead_ns
        total += t
        mem_total += m
        comp_total += c
        per_kind[op.kind] = per_kind.get(op.kind, 0.0) + t
        if op.kind in ("attn", "ffn"):
            b, ideal = lbr_acc.get(op.kind, (0.0, 0.0))
            lbr_acc[op.kind] = (b + op.read_bytes,
                                ideal + op.read_bytes / max(lbr, 1e-9))
    lbr_per_kind = {k: (b / ideal if ideal else 1.0)
                    for k, (b, ideal) in lbr_acc.items()}
    return StepTime(total, mem_total, comp_total, per_kind, lbr_per_kind)


# ---------------------------------------------------------------------------
# Public API (Fig 12 / Fig 13)
# ---------------------------------------------------------------------------

def tpot_ns(w: PaperWorkload, acc: AcceleratorSpec, batch: int,
            seq_len: int = 8192, n_devices: int = N_ACCELERATORS) -> StepTime:
    ops = decode_ops(w, batch, seq_len, n_devices)
    return step_time(ops, acc)


def prefill_ns(w: PaperWorkload, acc: AcceleratorSpec, batch: int,
               seq_len: int = 8192,
               n_devices: int = N_ACCELERATORS) -> StepTime:
    ops = prefill_ops(w, batch, seq_len, n_devices)
    return step_time(ops, acc)


def max_batch(w: PaperWorkload, seq_len: int = 8192,
              mem_capacity_gb: float = 256.0,
              n_devices: int = N_ACCELERATORS) -> int:
    """Largest power-of-two batch whose weights + KV fit system memory."""
    weights = _total_weight_bytes(w)
    cap = mem_capacity_gb * 1e9 * n_devices
    b = 1
    while True:
        kv = 2 * b * seq_len * w.kv_bytes_per_token_per_layer * w.n_layers
        if weights + kv > cap or b > 4096:
            return max(1, b // 2)
        b *= 2


def _total_weight_bytes(w: PaperWorkload) -> float:
    d = w.d_model
    attn = w.n_layers * (2 * d * (w.n_heads + w.n_kv_heads) * w.head_dim)
    if w.mla_kv_lora:
        attn = w.n_layers * (d * w.mla_q_lora
                             + w.mla_q_lora * w.n_heads * (w.head_dim + w.mla_rope_dim)
                             + d * (w.mla_kv_lora + w.mla_rope_dim)
                             + w.mla_kv_lora * w.n_heads * 2 * w.head_dim
                             + w.n_heads * w.head_dim * d)
    if w.is_moe:
        moe_layers = w.n_layers - w.n_dense_layers
        ffn = moe_layers * (w.n_experts + w.n_shared_experts) * 3 * d * w.d_ff
        ffn += w.n_dense_layers * 3 * d * w.dense_d_ff
    else:
        ffn = w.n_layers * 3 * d * w.d_ff
    return (attn + ffn + 2 * d * w.vocab) * w.bytes_per_param
