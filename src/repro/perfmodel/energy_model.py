"""DRAM energy comparison (paper Fig 14): HBM4 vs RoMe per decode step.

ACT counting: the physical minimum is one 1 KB bank-array activation per KB
for both systems (RoMe: 2 commands x 2 lockstep PCs per 4 KB row). The
conventional MC exceeds the minimum when many concurrent streams interleave
in its bounded queue: the per-stream window drops below a row's 32 columns,
rows get served in multiple visits, and intervening same-bank activity
forces re-activations. We *measure* that inflation with the cycle-level
engine (`act_inflation_curve`) and apply it per op by operand concurrency.
RoMe is structurally immune — one RD_row moves the whole row (§VI-C).
"""
from __future__ import annotations

import functools

import numpy as np

from ..configs.paper_workloads import PaperWorkload
from ..core import sched as eng
from ..core.analytic import calibrate
from ..core.energy import EnergyBreakdown, EnergyParams, hbm4_energy, rome_energy
from ..trace.layergraph import decode_ops
from .accelerator import AcceleratorSpec, N_ACCELERATORS, paper_accelerator
from .tpot import step_time

_STREAM_POINTS = (1, 4, 8, 12, 16, 20, 24, 28, 32)


@functools.lru_cache(maxsize=1)
def act_inflation_curve(queue_depth: int = 64,
                        nbytes_total: int = 1 << 18) -> dict:
    """Measured ACT/KB (minimum = 1.0) vs concurrent stream count."""
    out = {}
    for n in _STREAM_POINTS:
        txns = eng.interleaved_stream_txns_hbm4(n, max(1 << 15,
                                                       nbytes_total // n))
        r = eng.HBM4ChannelSim(queue_depth=queue_depth,
                               max_ref_postpone=32).run(txns)
        total_kb = len(txns) * 32 / 1024
        out[n] = r.cmd_counts["ACT"] / total_kb
    return out


def act_inflation(n_streams: int) -> float:
    """Measured ACT/KB multiplier (1.0 = structural minimum) at a given
    operand-stream concurrency. This is the same multiplier
    :func:`repro.core.analytic.transfer_time_ns` accepts as
    ``act_inflation`` — there it bounds the transfer by the row-command
    (ACT) issue path; here it scales per-op ACT energy (Fig 14)."""
    curve = act_inflation_curve()
    xs = np.array(sorted(curve))
    ys = np.array([curve[x] for x in xs])
    return float(np.interp(min(n_streams, xs[-1]), xs, ys))


def _op_concurrency(op) -> int:
    """Concurrent operand streams at the MC for one op.

    Attention: the 4 projection matrices + a handful of KV sequence streams
    the kernel has in flight + activation in/out. Dense FFN: operand tiles
    of a large GEMM + double-buffered prefetch (~14). MoE: each
    concurrently-issued small expert GEMM is its own weight stream — the
    accelerator pipelines many of them, which is why DeepSeek's 32
    active-experts-per-device decode shows the largest ACT inflation
    (paper Fig 14: ACT energy 55.5% vs Grok/Llama ~85%)."""
    n_ext = len(op.extents)
    if op.kind == "attn":
        return min(4 + min(n_ext - 1, 8) + 2, 32)
    if op.kind == "ffn" and n_ext > 2:          # MoE expert streams
        return min(2 + min(n_ext, 20), 32)
    return 14                                    # large dense GEMM



def decode_energy(w: PaperWorkload, batch: int, seq_len: int = 8192,
                  n_devices: int = N_ACCELERATORS,
                  params: EnergyParams = EnergyParams()) -> dict:
    """Per-device per-step energy under both systems. Returns
    {"hbm4": EnergyBreakdown, "rome": EnergyBreakdown, "act_ratio": float}.
    """
    ops = decode_ops(w, batch, seq_len, n_devices)
    acc_h = paper_accelerator("hbm4")
    acc_r = paper_accelerator("rome")
    st_h = step_time(ops, acc_h)
    st_r = step_time(ops, acc_r)
    eff_h = calibrate(acc_h.mem_cfg)
    eff_r = calibrate(acc_r.mem_cfg)

    bytes_rd = sum(op.read_bytes for op in ops)
    bytes_wr = sum(op.write_bytes for op in ops)
    bytes_all = bytes_rd + bytes_wr

    # HBM4: per-op inflated ACTs, 32 col commands per KB on the interposer.
    n_acts_h = 0.0
    for op in ops:
        infl = act_inflation(_op_concurrency(op))
        n_acts_h += (op.read_bytes + op.write_bytes) / 1024.0 * infl
    n_cols_h = bytes_all / 32.0
    refpb_h = eff_h.refpb_per_us * (st_h.total_ns / 1000.0) * acc_h.n_channels
    e_h = hbm4_energy(bytes_all, int(n_acts_h), int(n_cols_h), int(refpb_h),
                      st_h.total_ns, acc_h.n_channels, params)

    # RoMe: structural minimum; overfetch = row-rounding of every extent.
    n_rows = 0
    eff_bytes = 0
    for op in ops:
        for _, nb in op.extents:
            r = -(-nb // 4096)
            n_rows += r
            eff_bytes += r * 4096
        n_rows += -(-op.write_bytes // 4096)
        eff_bytes += -(-op.write_bytes // 4096) * 4096
    overfetch = eff_bytes / bytes_all - 1.0
    refpb_r = eff_r.refpb_per_us * (st_r.total_ns / 1000.0) * acc_r.n_channels
    e_r = rome_energy(bytes_all, n_rows, int(refpb_r), st_r.total_ns,
                      acc_r.n_channels, overfetch_frac=overfetch, p=params)

    return {
        "hbm4": e_h, "rome": e_r,
        "act_ratio": e_r.act_pj / e_h.act_pj,
        "total_ratio": e_r.total_pj / e_h.total_pj,
        "overfetch_frac": overfetch,
    }
