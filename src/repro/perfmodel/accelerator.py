"""Accelerator specifications (paper §VI-A and TPU v5e target).

Paper system: 8 accelerators, each 560 TFLOPS BF16 + 8 HBM4 cubes
(256 GB, 16 TB/s) => 280 Op/B arithmetic intensity (B200-class).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from ..core.address_map import AddressMap, make_address_map
from ..core.timing import MemSystemConfig, hbm4_config, rome_config


@dataclass(frozen=True)
class AcceleratorSpec:
    name: str
    bf16_tflops: float
    n_hbm_cubes: int
    mem_cfg: MemSystemConfig
    kernel_overhead_ns: float = 2_000.0   # per-op launch/sync overhead
    # Non-None pins the memory system to an exact channel count instead of
    # whole cubes — used by the scaled cross-validation systems whose
    # cycle-level SystemSim must match the perf model channel for channel.
    n_channels_override: int | None = None

    @property
    def peak_bw_gbps(self) -> float:
        if self.n_channels_override is not None:
            return self.n_channels_override * self.mem_cfg.channel_bw_gbps
        return self.mem_cfg.cube_bw_gbps * self.n_hbm_cubes

    @property
    def n_channels(self) -> int:
        if self.n_channels_override is not None:
            return self.n_channels_override
        return self.mem_cfg.channels_per_cube * self.n_hbm_cubes

    def address_map(self) -> AddressMap:
        """The stripe map of this accelerator's memory system — the one
        the TPOT model, LBR accounting, and SystemSim must all share."""
        amap = make_address_map(self.mem_cfg, self.n_hbm_cubes)
        if self.n_channels_override is not None:
            amap = dataclasses.replace(amap, n_channels=self.n_channels_override)
        return amap

    @property
    def op_per_byte(self) -> float:
        return self.bf16_tflops * 1e12 / (self.peak_bw_gbps * 1e9)


def paper_accelerator(mem: str = "hbm4") -> AcceleratorSpec:
    """§VI-A: 280 Op/B sustained at 16 TB/s (8 HBM4 cubes) => 4480 TFLOPS
    BF16 per accelerator. (The paper's '560 TFLOPS each' sentence is
    inconsistent with its own 280 Op/B target — 560 TF at 16 TB/s is
    35 Op/B, which would make batch-256 FFNs compute-bound and cap the
    Fig 12 TPOT gain far below the reported ~10 %; we follow the 280 Op/B
    spec, see DESIGN.md §2.)"""
    cfg = rome_config() if mem == "rome" else hbm4_config()
    return AcceleratorSpec(name=f"paper-accel-{mem}", bf16_tflops=4480.0,
                           n_hbm_cubes=8, mem_cfg=cfg)


def tpu_v5e(mem: str = "hbm4") -> AcceleratorSpec:
    """TPU v5e chip (the dry-run/roofline target): 197 TFLOP/s BF16,
    819 GB/s HBM. Modeled as a fractional cube at the same channel width."""
    cfg = rome_config() if mem == "rome" else hbm4_config()
    # 819 GB/s ~ 13 channels of 64 GB/s; keep one cube and scale by count.
    return AcceleratorSpec(name=f"tpu-v5e-{mem}", bf16_tflops=197.0,
                           n_hbm_cubes=1, mem_cfg=cfg,
                           kernel_overhead_ns=1_000.0)


def scaled_accelerator(mem: str = "hbm4", n_channels: int = 2,
                       op_per_byte: float = 280.0,
                       kernel_overhead_ns: float = 0.0) -> AcceleratorSpec:
    """A deliberately small system for cycle-level cross-validation: the
    same per-channel memory as the paper accelerator but only
    ``n_channels`` channels, with compute scaled to keep the §VI-A
    arithmetic intensity (so memory-/compute-boundedness of each layer op
    is preserved). SystemSim can simulate this system exactly, which is
    what lets ``perfmodel.tpot`` be validated against a measured
    multi-channel makespan (benchmarks/engine_xval.py)."""
    cfg = rome_config() if mem == "rome" else hbm4_config()
    peak_gbps = n_channels * cfg.channel_bw_gbps
    return AcceleratorSpec(
        name=f"xval-{mem}-{n_channels}ch",
        bf16_tflops=peak_gbps * op_per_byte / 1e3,   # GB/s * Op/B -> TFLOPS
        n_hbm_cubes=1, mem_cfg=cfg,
        kernel_overhead_ns=kernel_overhead_ns,
        n_channels_override=n_channels)


N_ACCELERATORS = 8   # the paper's serving system size
