"""AST-based repo-invariant lints.

These machine-check the policies the repo records in prose (CHANGES.md,
docs/compat.md) but nothing previously enforced:

``jax-drift``
    Drifted JAX API symbols must be adapted *exactly once*, in
    :mod:`repro.compat` (the PR 1 policy). Using ``jax.tree.map``,
    ``jax.make_mesh``, ``jax.sharding.get_abstract_mesh``,
    ``pltpu.TPUCompilerParams``, ``.cost_analysis()`` etc. anywhere else
    reintroduces a per-call-site version dependency.
``version-compare``
    Feature detection over version-string comparison — ``__version__``
    parsing breaks on rc/dev suffixes and lies about backports.
``unseeded-random``
    Module-level (global-state) RNG calls in ``core/`` / ``serve/``:
    the hybrid bit-identity contract and the StepPricer memoization both
    assume runs are deterministic functions of their inputs. Seeded
    ``np.random.default_rng(seed)`` generators are fine.
``mutable-default``
    Mutable default arguments (lists/dicts/sets) shared across calls.
``pool-submit-closure``
    Lambdas / nested functions handed to ``.submit(...)``: the process
    pools in :mod:`repro.core.pool` need picklable (module-level)
    callables; closures die with an opaque pickling error at the first
    real fan-out.
``untracked-counter``
    (``repro/core/sched`` only) Every command-counter key a policy
    touches — ``counts["K"]`` subscripts, ``cmd_counts.get("K")``
    reads, ``count_keys`` tuple entries — must be declared in
    :data:`repro.obs.metrics.COUNTER_REGISTRY`. The registry is what
    the telemetry probe folds, exports and documents; a key that only
    exists in a policy's hot loop silently vanishes from every trace.

Markdown docs get their own two rules (:func:`lint_docs`, also wired
into ``scripts/lint.py``):

``doc-code-block``
    Every fenced ```` ```python ```` block in ``README.md`` /
    ``docs/*.md`` must ``ast.parse`` — documentation code that has
    drifted into syntax errors is worse than none.
``doc-path``
    Every repo path a doc names (``src/...``, ``benchmarks/...``,
    ``scripts/...``, ``docs/...``, ``tests/...``) must exist — stale
    file pointers are how architecture docs rot.

Use :func:`lint_paths` (or ``scripts/lint.py``). Findings carry
(path, line, rule, message) and are deterministic and sorted.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable, NamedTuple


class LintFinding(NamedTuple):
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


#: Dotted drifted-API chains -> the repro.compat replacement. Chains are
#: matched against fully-resolved attribute paths rooted at a module
#: alias (``import jax`` / ``import jax.sharding`` both resolve).
DRIFTED_CHAINS = {
    "jax.tree.map": "repro.compat.tree_map",
    "jax.tree_util.tree_map": "repro.compat.tree_map",
    "jax.make_mesh": "repro.compat.make_mesh",
    "jax.set_mesh": "repro.compat.set_mesh",
    "jax.sharding.use_mesh": "repro.compat.set_mesh",
    "jax.sharding.get_abstract_mesh": "repro.compat.active_mesh",
    "jax.shard_map": "repro.compat.shard_map",
}

#: Drifted attribute *names* (the owning module moved across versions).
DRIFTED_ATTRS = {
    "TPUCompilerParams": "repro.compat.tpu_compiler_params",
    "CompilerParams": "repro.compat.tpu_compiler_params",
    "axis_sizes": "repro.compat.mesh_axis_sizes",
}

#: Drifted method calls (result shape / existence varies by version).
DRIFTED_METHOD_CALLS = {
    "cost_analysis": "repro.compat.xla_cost_analysis / "
                     "normalize_cost_analysis",
}

#: ``from <module> import <name>`` pairs that smuggle drifted symbols in
#: under a local alias.
DRIFTED_IMPORTS = {
    ("jax", "make_mesh"), ("jax", "set_mesh"), ("jax", "shard_map"),
    ("jax.tree_util", "tree_map"),
    ("jax.sharding", "use_mesh"), ("jax.sharding", "get_abstract_mesh"),
}

#: numpy legacy global-RNG functions (process-wide state).
_NP_LEGACY = {
    "seed", "random", "rand", "randn", "randint", "random_sample",
    "ranf", "sample", "choice", "shuffle", "permutation", "normal",
    "uniform", "standard_normal", "poisson", "exponential", "beta",
    "binomial", "gamma", "geometric", "lognormal",
}

#: stdlib ``random`` module-level functions (shared Mersenne state).
_PY_RANDOM = {
    "seed", "random", "randint", "randrange", "uniform", "choice",
    "choices", "shuffle", "sample", "gauss", "normalvariate",
    "expovariate", "betavariate", "paretovariate", "triangular",
    "getrandbits",
}

ALL_RULES = ("jax-drift", "version-compare", "unseeded-random",
             "mutable-default", "pool-submit-closure",
             "untracked-counter")


def _registered_counters() -> frozenset[str]:
    """Names declared in repro.obs.metrics.COUNTER_REGISTRY (imported
    lazily so the linter stays importable standalone)."""
    global _COUNTERS
    if _COUNTERS is None:
        from repro.obs.metrics import COUNTER_REGISTRY
        _COUNTERS = frozenset(COUNTER_REGISTRY)
    return _COUNTERS


_COUNTERS: frozenset[str] | None = None

#: Markdown-doc rules (separate from the Python AST rules above; see
#: :func:`lint_docs`).
DOC_RULES = ("doc-code-block", "doc-path")


def _is_counts_chain(chain: str | None) -> bool:
    """True for dotted chains naming a command-counter dict:
    ``counts``, ``self.counts``, ``cmd_counts``, ``res.cmd_counts``…"""
    if chain is None:
        return False
    last = chain.split(".")[-1]
    return last == "counts" or last.endswith("_counts")


def _dotted(node: ast.AST) -> str | None:
    """Resolve an Attribute/Name chain to ``a.b.c`` or None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, rules: Iterable[str]):
        self.path = path
        self.rules = set(rules)
        self.findings: list[LintFinding] = []
        self._imports: set[str] = set()       # imported top-level modules
        self._func_stack: list[ast.AST] = []
        self._nested_defs: set[str] = set()   # names of nested functions

    def add(self, rule: str, node: ast.AST, msg: str) -> None:
        if rule in self.rules:
            self.findings.append(
                LintFinding(self.path, getattr(node, "lineno", 0), rule, msg))

    # -- imports -----------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self._imports.add(alias.asname or alias.name.split(".")[0])
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        for alias in node.names:
            if (node.module, alias.name) in DRIFTED_IMPORTS:
                self.add("jax-drift", node,
                         f"import of drifted symbol "
                         f"{node.module}.{alias.name} — use "
                         f"{DRIFTED_CHAINS.get(f'{node.module}.{alias.name}', 'repro.compat')}")
        self.generic_visit(node)

    # -- drifted attribute chains ------------------------------------------

    def visit_Attribute(self, node: ast.Attribute) -> None:
        chain = _dotted(node)
        if chain is not None:
            hit = DRIFTED_CHAINS.get(chain)
            if hit is not None:
                self.add("jax-drift", node,
                         f"drifted JAX API {chain} outside repro.compat "
                         f"— use {hit}")
                return  # don't re-flag inner attributes
        if node.attr in DRIFTED_ATTRS and (
                chain is None or not chain.startswith(("self.", "cls."))):
            self.add("jax-drift", node,
                     f"drifted attribute .{node.attr} outside repro.compat "
                     f"— use {DRIFTED_ATTRS[node.attr]}")
        self.generic_visit(node)

    # -- calls -------------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        chain = _dotted(fn) if isinstance(fn, (ast.Attribute, ast.Name)) \
            else None
        if isinstance(fn, ast.Attribute):
            if fn.attr in DRIFTED_METHOD_CALLS:
                self.add("jax-drift", node,
                         f".{fn.attr}() call outside repro.compat — use "
                         f"{DRIFTED_METHOD_CALLS[fn.attr]}")
            if fn.attr == "submit" and node.args:
                self._check_submit(node)
            if fn.attr == "get" and _is_counts_chain(_dotted(fn.value)) \
                    and node.args:
                self._check_counter_key(node.args[0])
        if chain is not None:
            self._check_random(node, chain)
        self.generic_visit(node)

    # -- counter registry --------------------------------------------------

    def _check_counter_key(self, key_node: ast.AST) -> None:
        if "untracked-counter" not in self.rules:
            return
        if isinstance(key_node, ast.Constant) \
                and isinstance(key_node.value, str) \
                and key_node.value not in _registered_counters():
            self.add("untracked-counter", key_node,
                     f"counter key {key_node.value!r} is not declared in "
                     f"repro.obs.metrics.COUNTER_REGISTRY — the probe "
                     f"would silently drop it from every trace")

    def visit_Subscript(self, node: ast.Subscript) -> None:
        if _is_counts_chain(_dotted(node.value)):
            self._check_counter_key(node.slice)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        if any(isinstance(t, ast.Name) and t.id == "count_keys"
               for t in node.targets):
            for const in ast.walk(node.value):
                if isinstance(const, ast.Constant):
                    self._check_counter_key(const)
        self.generic_visit(node)

    def _check_random(self, node: ast.Call, chain: str) -> None:
        parts = chain.split(".")
        root = parts[0]
        if root in ("np", "numpy") and len(parts) == 3 \
                and parts[1] == "random":
            if parts[2] in _NP_LEGACY:
                self.add("unseeded-random", node,
                         f"global-state numpy RNG {chain}() — use a seeded "
                         f"np.random.default_rng(seed) generator")
            elif parts[2] == "default_rng" and not node.args:
                self.add("unseeded-random", node,
                         "np.random.default_rng() without a seed — "
                         "nondeterministic across runs")
        elif root == "random" and len(parts) == 2 \
                and "random" in self._imports and parts[1] in _PY_RANDOM:
            self.add("unseeded-random", node,
                     f"stdlib global RNG {chain}() — use a seeded "
                     f"random.Random(seed) (or np.random.default_rng)")

    def _check_submit(self, node: ast.Call) -> None:
        arg = node.args[0]
        if isinstance(arg, ast.Lambda):
            self.add("pool-submit-closure", node,
                     "lambda handed to .submit() — process pools need a "
                     "picklable module-level callable")
        elif isinstance(arg, ast.Name) and arg.id in self._nested_defs:
            self.add("pool-submit-closure", node,
                     f"nested function {arg.id!r} handed to .submit() — "
                     f"process pools need a module-level callable")

    # -- comparisons -------------------------------------------------------

    def visit_Compare(self, node: ast.Compare) -> None:
        for side in [node.left, *node.comparators]:
            # Unwrap subscripts/calls like __version__.split(".")[0].
            inner = side
            while isinstance(inner, (ast.Subscript, ast.Call)):
                inner = inner.value if isinstance(inner, ast.Subscript) \
                    else inner.func
            chain = _dotted(inner)
            if chain and chain.split(".")[-1] in ("__version__", "split"):
                base = _dotted(inner.value) if isinstance(inner, ast.Attribute) \
                    else None
                if "__version__" in chain or (base and "__version__" in base):
                    self.add("version-compare", node,
                             f"comparison against {chain} — feature-detect "
                             f"in repro.compat instead of parsing versions")
                    break
        self.generic_visit(node)

    # -- defs --------------------------------------------------------------

    def _visit_func(self, node) -> None:
        if self._func_stack:
            self._nested_defs.add(node.name)
        for default in [*node.args.defaults, *node.args.kw_defaults]:
            if default is None:
                continue
            bad = None
            if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                bad = type(default).__name__.lower() + " literal"
            elif isinstance(default, ast.Call):
                callee = _dotted(default.func)
                if callee in ("list", "dict", "set", "bytearray",
                              "collections.defaultdict"):
                    bad = f"{callee}() call"
            if bad:
                self.add("mutable-default", default,
                         f"mutable default argument ({bad}) in "
                         f"{node.name}() — default to None and build "
                         f"inside the function")
        self._func_stack.append(node)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func


def lint_source(source: str, path: str = "<string>",
                rules: Iterable[str] = ALL_RULES) -> list[LintFinding]:
    """Lint one source string; returns findings sorted by line."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [LintFinding(path, e.lineno or 0, "syntax-error", str(e.msg))]
    linter = _Linter(path, rules)
    linter.visit(tree)
    return sorted(linter.findings, key=lambda f: (f.line, f.rule))


def rules_for_path(path: str, root: str = "") -> tuple[str, ...]:
    """Which rules apply where.

    * ``jax-drift`` everywhere under ``src/repro`` except
      ``repro/compat`` (the one place drifted symbols are *supposed* to
      appear) — plus benchmarks/scripts/tests, which must also route
      through the adapters.
    * ``unseeded-random`` only in the determinism-critical packages
      (``repro/core``, ``repro/serve``) — tests and benchmarks may roll
      dice however they like (they seed at the call site).
    * ``untracked-counter`` only where counter keys are minted:
      ``repro/core/sched`` (policies and the engine cores).
    * everything else applies everywhere.
    """
    p = Path(path).as_posix()
    rules = ["version-compare", "mutable-default", "pool-submit-closure"]
    if "repro/compat" not in p:
        rules.append("jax-drift")
    if "repro/core" in p or "repro/serve" in p:
        rules.append("unseeded-random")
    if "repro/core/sched" in p:
        rules.append("untracked-counter")
    return tuple(rules)


def lint_paths(paths: Iterable[str | Path]) -> list[LintFinding]:
    """Lint ``.py`` files (recursing into directories); deterministic
    order."""
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    findings: list[LintFinding] = []
    for f in files:
        rel = f.as_posix()
        findings.extend(
            lint_source(f.read_text(), rel, rules=rules_for_path(rel)))
    return findings


# -- markdown docs ----------------------------------------------------------

_FENCE_RE = re.compile(r"^\s*```([A-Za-z0-9_+-]*)\s*$")

#: Repo-relative path mentions a doc can make; extensions are limited to
#: the kinds the repo actually tracks so prose like "x/y.z" can't
#: misfire.
_DOC_PATH_RE = re.compile(
    r"\b(?:src|benchmarks|scripts|docs|tests)"
    r"/[\w./-]*\.(?:py|md|sh|json|yml|yaml|txt)\b")


def lint_doc_source(text: str, path: str = "<doc>",
                    repo_root: str | Path | None = None
                    ) -> list[LintFinding]:
    """Lint one markdown document (:data:`DOC_RULES`).

    Fenced ```` ```python ```` blocks must :func:`ast.parse` (findings
    point at the offending line inside the block); with ``repo_root``
    given, every repo-relative path mention — prose and code fences
    alike — must exist on disk.
    """
    findings: list[LintFinding] = []
    root = Path(repo_root) if repo_root is not None else None
    fence_lang: str | None = None
    block: list[str] = []
    block_start = 0
    for lineno, line in enumerate(text.splitlines(), start=1):
        m = _FENCE_RE.match(line)
        if m and fence_lang is None:
            fence_lang = m.group(1).lower()
            block, block_start = [], lineno + 1
            continue
        if m:
            if fence_lang in ("python", "py"):
                try:
                    ast.parse("\n".join(block), filename=path)
                except SyntaxError as e:
                    findings.append(LintFinding(
                        path, block_start + (e.lineno or 1) - 1,
                        "doc-code-block",
                        f"python block does not parse: {e.msg}"))
            fence_lang = None
            continue
        if fence_lang is not None:
            block.append(line)
        if root is not None:
            for pm in _DOC_PATH_RE.finditer(line):
                if not (root / pm.group(0)).exists():
                    findings.append(LintFinding(
                        path, lineno, "doc-path",
                        f"doc names {pm.group(0)} but no such file "
                        f"exists in the repo"))
    return sorted(findings, key=lambda f: (f.line, f.rule))


def lint_docs(paths: Iterable[str | Path],
              repo_root: str | Path | None = None) -> list[LintFinding]:
    """Lint ``.md`` files (recursing into directories); deterministic
    order. ``repo_root`` anchors the ``doc-path`` existence checks (pass
    the repo checkout root)."""
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.suffix == ".md":
            files.append(p)
    findings: list[LintFinding] = []
    for f in files:
        findings.extend(
            lint_doc_source(f.read_text(), f.as_posix(),
                            repo_root=repo_root))
    return findings
