"""Independent timing-protocol verification of emitted command traces.

The scheduler policies in :mod:`repro.core.sched.policies` compute their
own readiness clocks — a single optimistic off-by-one there silently
inflates HBM4 or RoMe bandwidth and corrupts the paper's central
comparison. This module re-derives command-stream legality from the
timing dataclasses alone: it never calls into the policy code, and the
rule set is a declarative table (:class:`GapRule` entries built straight
from :class:`~repro.core.timing.HBM4Timing` /
:class:`~repro.core.timing.RoMeTiming` fields) plus a handful of
structural checks that cannot be expressed as a pairwise gap (rolling
tFAW window, bank/row state, DQ-bus occupancy, bounded refresh
postponement).

Granularity matches what each MC actually schedules:

* HBM4 policies are checked at DRAM-command level (ACT/RD/WR/PRE/REF)
  against the JEDEC-style Table V parameters.
* The RoMe policy is checked at row-command level (RD_row/WR_row/REF)
  against the published Table III row-to-row gaps — Table III *is* its
  protocol; the intra-row DRAM expansion is statically derived (and
  separately verified) in :mod:`repro.core.command_generator`.

Traces are emitted per command *site*, not in global time order (the
column C/A path may legally land a command before ``now``; refresh
issues are anchored at their backdated due times), so the checker sorts
by timestamp before replaying.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

from ..core.timing import ChannelGeometry, HBM4Timing, RoMeTiming

#: Float-time comparison slack (ns). Command times are exact IEEE sums of
#: the same parameters the rules use, so anything beyond rounding noise
#: is a real violation.
EPS = 1e-6


class Violation(NamedTuple):
    rule: str
    t_ns: float
    bank: int
    detail: str


class TimingProtocolError(AssertionError):
    """Raised by sanitizer mode (``SystemSim(check_timing=True)``)."""

    def __init__(self, report: "CheckReport"):
        self.report = report
        super().__init__(report.summary())


@dataclass(frozen=True)
class GapRule:
    """One declarative minimum-gap rule.

    For every command whose op is in ``ops``, the elapsed time since the
    most recent ``event`` in the rule's ``scope`` must be at least
    ``gap_ns``:

    ``scope``
        ``"bank"`` — same bank / VBA; ``"pc"`` — same pseudo channel;
        ``"bg"`` — same (pc, bank group); ``"xsid"`` — same pseudo
        channel, *different* SID; ``"ch"`` — whole channel.
    ``event``
        Register name: ``"ACT"``, ``"PRE"``, ``"RD"`` (last RD command),
        ``"WR_data_end"`` (last write's final data beat), ``"burst"``
        (last RD or WR command), ``"REF"``.
    """

    name: str
    ops: frozenset
    scope: str
    event: str
    gap_ns: float


@dataclass
class CheckReport:
    """Per-rule violation census for one replayed trace."""

    kind: str
    n_commands: int = 0
    counts: dict = field(default_factory=dict)      # rule -> n violations
    violations: list = field(default_factory=list)  # first `max_keep`
    max_keep: int = 50

    @property
    def ok(self) -> bool:
        return not self.counts

    def add(self, rule: str, t_ns: float, bank: int, detail: str) -> None:
        self.counts[rule] = self.counts.get(rule, 0) + 1
        if len(self.violations) < self.max_keep:
            self.violations.append(Violation(rule, t_ns, bank, detail))

    def merge(self, other: "CheckReport") -> None:
        self.n_commands += other.n_commands
        for rule, n in other.counts.items():
            self.counts[rule] = self.counts.get(rule, 0) + n
        keep = self.max_keep - len(self.violations)
        if keep > 0:
            self.violations.extend(other.violations[:keep])

    def summary(self) -> str:
        if self.ok:
            return (f"{self.kind}: {self.n_commands} commands, "
                    f"0 violations")
        rules = ", ".join(f"{k}×{v}" for k, v in sorted(self.counts.items()))
        first = "; ".join(
            f"{v.rule}@{v.t_ns:.3f}ns bank {v.bank}: {v.detail}"
            for v in self.violations[:5])
        return (f"{self.kind}: {self.n_commands} commands, "
                f"{sum(self.counts.values())} violations ({rules}) — {first}")


def _sorted(trace) -> list:
    return sorted(trace, key=lambda r: r.t_ns)


# ===========================================================================
# HBM4: DRAM-command-level JEDEC rules
# ===========================================================================

class HBM4TraceChecker:
    """Replays an ACT/RD/WR/PRE/REF trace against the Table V rule table.

    Mirrors the *model's* resource scoping (which is no looser than
    JEDEC's): bank-core rules are keyed on the flat bank id, burst/ACT
    spacing on the pseudo channel and (pc, bank group), tCCDR across SIDs
    sharing a pseudo channel, tFAW as a rolling 4-ACT window per pseudo
    channel, and the DQ data bus as one exclusive resource per pseudo
    channel.
    """

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None,
                 refresh: bool = True,
                 ref_period: float | None = None,
                 max_ref_postpone: int = 8):
        t = self.t = timing or HBM4Timing()
        self.g = geometry or ChannelGeometry()
        self.refresh = refresh
        self.ref_period = ref_period if ref_period is not None else t.tREFIpb
        self.max_ref_postpone = max_ref_postpone
        col = frozenset({"RD", "WR"})
        self.rules = (
            # Bank core
            GapRule("tRCDRD", frozenset({"RD"}), "bank", "ACT", t.tRCDRD),
            GapRule("tRCDWR", frozenset({"WR"}), "bank", "ACT", t.tRCDWR),
            GapRule("tRAS", frozenset({"PRE"}), "bank", "ACT", t.tRAS),
            GapRule("tRP", frozenset({"ACT", "REF"}), "bank", "PRE", t.tRP),
            GapRule("tRTP", frozenset({"PRE"}), "bank", "RD", t.tRTP),
            GapRule("tWR", frozenset({"PRE"}), "bank", "WR_data_end", t.tWR),
            # Refresh blackout: nothing touches the bank during tRFCpb.
            GapRule("tRFCpb", frozenset({"ACT", "RD", "WR", "PRE", "REF"}),
                    "bank", "REF", t.tRFCpb),
            GapRule("tRREFpb", frozenset({"REF"}), "ch", "REF", t.tRREFpb),
            # Column command spacing
            GapRule("tCCDS", col, "pc", "burst", t.tCCDS),
            GapRule("tCCDL", col, "bg", "burst", t.tCCDL),
            GapRule("tCCDR", col, "xsid", "burst", t.tCCDR),
            # Activation spacing
            GapRule("tRRDS", frozenset({"ACT"}), "pc", "ACT", t.tRRDS),
            GapRule("tRRDL", frozenset({"ACT"}), "bg", "ACT", t.tRRDL),
            # Bus turnarounds
            GapRule("tRTW", frozenset({"WR"}), "pc", "RD", t.tRTW),
            GapRule("tWTRS", frozenset({"RD"}), "pc", "WR_data_end", t.tWTRS),
            GapRule("tWTRL", frozenset({"RD"}), "bg", "WR_data_end", t.tWTRL),
        )
        self._by_op: dict[str, list[GapRule]] = {}
        for rule in self.rules:
            for op in rule.ops:
                self._by_op.setdefault(op, []).append(rule)

    def _bg(self, bank: int) -> int:
        return (bank % self.g.banks_per_pc) // self.g.banks_per_group

    def check(self, trace) -> CheckReport:
        rep = CheckReport("hbm4")
        recs = _sorted(trace)
        rep.n_commands = len(recs)
        t_faw = self.t.tFAW
        bank_ev: dict[int, dict] = {}
        pc_ev: dict[int, dict] = {}
        bg_ev: dict[tuple, dict] = {}
        ch_ev: dict = {}
        sid_burst: dict[int, dict] = {}
        open_row: dict[int, int] = {}
        pc_acts: dict[int, list] = {}
        windows: dict[int, list] = {}
        ref_times: list[float] = []
        by_op = self._by_op

        for rec in recs:
            t, op, b, pc = rec.t_ns, rec.op, rec.bank, rec.pc
            bg = (pc, self._bg(b))
            for rule in by_op.get(op, ()):
                scope = rule.scope
                if scope == "bank":
                    ref = bank_ev.get(b, {}).get(rule.event)
                elif scope == "pc":
                    ref = pc_ev.get(pc, {}).get(rule.event)
                elif scope == "bg":
                    ref = bg_ev.get(bg, {}).get(rule.event)
                elif scope == "ch":
                    ref = ch_ev.get(rule.event)
                else:  # xsid: most recent burst by any *other* SID
                    ref = None
                    for s, tb in sid_burst.get(pc, {}).items():
                        if s != rec.sid and (ref is None or tb > ref):
                            ref = tb
                if ref is not None and t - ref < rule.gap_ns - EPS:
                    rep.add(rule.name, t, b,
                            f"{op} {t - ref:.3f}ns after {rule.event} "
                            f"(min {rule.gap_ns})")

            if op == "ACT":
                if open_row.get(b) is not None:
                    rep.add("bank-state", t, b, "ACT on bank with open row")
                acts = pc_acts.setdefault(pc, [])
                if len(acts) >= 4 and t - acts[-4] < t_faw - EPS:
                    rep.add("tFAW", t, b,
                            f"5th ACT {t - acts[-4]:.3f}ns into a "
                            f"{t_faw}ns window")
                acts.append(t)
                if len(acts) > 8:
                    del acts[0]
                open_row[b] = rec.row
                bank_ev.setdefault(b, {})["ACT"] = t
                pc_ev.setdefault(pc, {})["ACT"] = t
                bg_ev.setdefault(bg, {})["ACT"] = t
            elif op in ("RD", "WR"):
                if open_row.get(b) != rec.row:
                    rep.add("row-state", t, b,
                            f"{op} row {rec.row} but open row is "
                            f"{open_row.get(b)}")
                bev = bank_ev.setdefault(b, {})
                pev = pc_ev.setdefault(pc, {})
                gev = bg_ev.setdefault(bg, {})
                pev["burst"] = gev["burst"] = t
                sid_burst.setdefault(pc, {})[rec.sid] = t
                if op == "WR":
                    bev["WR_data_end"] = rec.data_end_ns
                    pev["WR_data_end"] = rec.data_end_ns
                    gev["WR_data_end"] = rec.data_end_ns
                else:
                    bev["RD"] = pev["RD"] = t
                windows.setdefault(pc, []).append(
                    (rec.data_start_ns, rec.data_end_ns))
            elif op == "PRE":
                if open_row.get(b) is None:
                    rep.add("bank-state", t, b, "PRE on precharged bank")
                open_row[b] = None
                bank_ev.setdefault(b, {})["PRE"] = t
            elif op == "REF":
                if open_row.get(b) is not None:
                    rep.add("bank-state", t, b, "REF on bank with open row")
                bank_ev.setdefault(b, {})["REF"] = t
                ch_ev["REF"] = t
                ref_times.append(t)
            else:
                rep.add("unknown-op", t, b, f"unexpected op {op!r}")

        for pc, wins in windows.items():
            _check_bus(rep, wins, f"pc {pc}")
        if self.refresh:
            _check_refresh_debt(rep, ref_times, recs, self.ref_period,
                                self.max_ref_postpone)
        return rep


# ===========================================================================
# RoMe: row-command-level Table III rules
# ===========================================================================

#: (prev_is_write, next_is_write, same_sid) -> Table III parameter name.
ROME_GAP_NAMES = {
    (False, False, True): "tR2RS", (False, False, False): "tR2RR",
    (False, True, True): "tR2WS", (False, True, False): "tR2WR",
    (True, False, True): "tW2RS", (True, False, False): "tW2RR",
    (True, True, True): "tW2WS", (True, True, False): "tW2WR",
}


class RoMeTraceChecker:
    """Replays a RD_row/WR_row/REF trace against Table III.

    Rules:

    * consecutive row commands (channel C/A order) must respect the
      Table III start-to-start gap for their (prev kind, next kind,
      same-SID) pair;
    * a row command to a VBA must wait out that VBA's previous service
      time (tRD_row / tWR_row) and any refresh window
      (tRFCpb + tRREFpb) regardless of interveners;
    * REF must not start while its VBA is mid-access, and two REFs to
      the same VBA are spaced by the full refresh window;
    * VBA-refresh starts keep 2*tRREFpb on the C/A path (each expands
      to two REFpb commands tRREFpb apart), and no more than
      ``RoMeTiming.max_concurrent_refreshing()`` refresh windows overlap
      — the MC provisions exactly that many refresh FSMs (§V-A);
    * same-direction data-bus windows must not overlap (mixed-direction
      spacing is owned by the Table III R2W/W2R gaps themselves — see
      docs/timing_sanitizer.md on the tCWL offset);
    * refresh postponement stays bounded.
    """

    def __init__(self, timing: RoMeTiming | None = None,
                 n_vbas: int = 16,
                 refresh: bool = True,
                 ref_period: float | None = None,
                 max_ref_postpone: int = 8):
        t = self.t = timing or RoMeTiming()
        self.n_vbas = n_vbas
        self.refresh = refresh
        self.ref_period = (ref_period if ref_period is not None
                           else 2 * t.tREFIpb)
        self.max_ref_postpone = max_ref_postpone
        self.ref_window = t.tRFCpb + t.tRREFpb
        self.ref_cap = t.max_concurrent_refreshing()

    def check(self, trace) -> CheckReport:
        rep = CheckReport("rome")
        recs = _sorted(trace)
        rep.n_commands = len(recs)
        t = self.t
        prev = None                      # last row command (any VBA)
        vba_last: dict[int, tuple] = {}  # vba -> (t, is_write)
        vba_ref_end: dict[int, float] = {}
        vba_ref_t: dict[int, float] = {}
        windows: dict[bool, list] = {False: [], True: []}
        ref_times: list[float] = []

        for rec in recs:
            tn, b = rec.t_ns, rec.bank
            if rec.op in ("RD_row", "WR_row"):
                w = rec.op == "WR_row"
                if prev is not None:
                    pt, pw, pb, ps = prev
                    gap = t.gap_ns(pw, w, same_vba=(b == pb),
                                   same_sid=(rec.sid == ps))
                    if b == pb:
                        name = "tWR_row" if pw else "tRD_row"
                    else:
                        name = ROME_GAP_NAMES[(pw, w, rec.sid == ps)]
                    if tn - pt < gap - EPS:
                        rep.add(name, tn, b,
                                f"{rec.op} {tn - pt:.3f}ns after previous "
                                f"row command (min {gap})")
                # Same-VBA service time vs this VBA's last access even
                # with interveners (the consecutive-pair rule above
                # already covered the no-intervener case).
                last = vba_last.get(b)
                if last is not None and not (prev is not None
                                             and prev[2] == b):
                    svc = t.tWR_row if last[1] else t.tRD_row
                    if tn - last[0] < svc - EPS:
                        rep.add("tWR_row" if last[1] else "tRD_row", tn, b,
                                f"{rec.op} {tn - last[0]:.3f}ns after "
                                f"previous access to VBA (min {svc})")
                ref_end = vba_ref_end.get(b)
                if ref_end is not None and tn < ref_end - EPS:
                    rep.add("ref-blackout", tn, b,
                            f"{rec.op} during refresh window ending "
                            f"{ref_end:.3f}ns")
                prev = (tn, w, b, rec.sid)
                vba_last[b] = (tn, w)
                windows[w].append((rec.data_start_ns, rec.data_end_ns))
            elif rec.op == "REF":
                last = vba_last.get(b)
                if last is not None:
                    svc = t.tWR_row if last[1] else t.tRD_row
                    if tn - last[0] < svc - EPS:
                        rep.add("ref-vba-busy", tn, b,
                                f"REF {tn - last[0]:.3f}ns after access "
                                f"(min {svc})")
                last_ref = vba_ref_t.get(b)
                if last_ref is not None and \
                        tn - last_ref < self.ref_window - EPS:
                    rep.add("ref-ref-gap", tn, b,
                            f"REF {tn - last_ref:.3f}ns after previous "
                            f"REF to VBA (min {self.ref_window})")
                if ref_times and tn - ref_times[-1] < 2 * t.tRREFpb - EPS:
                    rep.add("ref-ref-ch", tn, b,
                            f"VBA-refresh {tn - ref_times[-1]:.3f}ns after "
                            f"previous start (min {2 * t.tRREFpb})")
                vba_ref_t[b] = tn
                vba_ref_end[b] = tn + self.ref_window
                ref_times.append(tn)
            else:
                rep.add("unknown-op", tn, b, f"unexpected op {rec.op!r}")

        for w, wins in windows.items():
            _check_bus(rep, wins, "WR" if w else "RD")
        # Refresh-FSM provisioning: at most `ref_cap` windows in flight.
        active: list[float] = []
        for tn in ref_times:           # already sorted (emission order)
            active = [e for e in active if e > tn + EPS]
            if len(active) >= self.ref_cap:
                rep.add("ref-concurrency", tn, -1,
                        f"{len(active) + 1} refresh windows in flight "
                        f"(cap {self.ref_cap})")
            active.append(tn + self.ref_window)
        if self.refresh:
            _check_refresh_debt(rep, ref_times, recs, self.ref_period,
                                self.max_ref_postpone)
        return rep


# ===========================================================================
# Shared structural checks
# ===========================================================================

def _check_bus(rep: CheckReport, wins: list, label: str) -> None:
    """Exclusive-resource occupancy: sorted data windows must not
    overlap. Emission order need not be data order (write latency <<
    read latency), so sort by window start."""
    wins = sorted(w for w in wins if w[0] >= 0.0)
    for (s0, e0), (s1, e1) in zip(wins, wins[1:]):
        if s1 < e0 - EPS:
            rep.add("dq-overlap", s1, -1,
                    f"{label}: data window [{s1:.3f}, {e1:.3f}] overlaps "
                    f"previous ending {e0:.3f}")


def _check_refresh_debt(rep: CheckReport, ref_times: list, recs: list,
                        period: float, max_postpone: int) -> None:
    """Bounded refresh postponement.

    The governor owes one refresh per elapsed ``period``; JEDEC-style
    bounded postponement allows at most ``max_postpone`` of them to be
    outstanding under demand. Refresh issues are anchored at their due
    times, so debt is observable straight from the trace: at the i-th
    REF (0-based), dues(start_i) - i must stay within the bound, and at
    the end of the trace the leftover debt must too. Slack of +2 covers
    the transient between the governor's accrual step and its same-
    iteration drain (clock advances are bounded by tRFCpb > 2 periods).
    """
    if not recs:
        return
    bound = max_postpone + 2
    for i, tr in enumerate(sorted(ref_times)):
        debt = int(tr / period) - i
        if debt > bound:
            rep.add("ref-postpone", tr, -1,
                    f"{debt} refreshes overdue at {tr:.3f}ns "
                    f"(bound {bound})")
    t_end = max(r.t_ns for r in recs)
    debt = int(t_end / period) - len(ref_times)
    if debt > bound:
        rep.add("ref-postpone", t_end, -1,
                f"{debt} refreshes never issued by end of trace "
                f"(bound {bound})")


# ===========================================================================
# Factories
# ===========================================================================

def checker_for_sim(sim):
    """Build the matching checker for a constructed channel sim, reading
    only its *configuration* (timing tables, geometry, refresh knobs) —
    never its scheduling state."""
    from ..core.sched.policies import RoMeRowPolicy
    pol = sim.policy
    if isinstance(pol, RoMeRowPolicy):
        return RoMeTraceChecker(pol.t, n_vbas=pol.n_vbas,
                                refresh=sim.refresh,
                                ref_period=pol.ref_period,
                                max_ref_postpone=sim.max_ref_postpone)
    return HBM4TraceChecker(pol.t, pol.g, refresh=sim.refresh,
                            ref_period=pol.ref_period,
                            max_ref_postpone=sim.max_ref_postpone)


def check_sim_result(sim, result, label: str = "") -> CheckReport:
    """Check one SimResult's trace; raises if the run wasn't traced."""
    if result.trace is None:
        raise ValueError(
            f"{label or 'run'} has no command trace — construct the sim "
            f"with emit_trace=True (or SystemSim(check_timing=True))")
    rep = checker_for_sim(sim).check(result.trace)
    if label:
        rep.kind = label
    return rep
