"""Static analysis of the repro itself: trace sanitizing + repo lints.

Two independent verifiers live here, both deliberately *outside* the
code they check:

``timing_checker``
    Replays a :class:`~repro.core.sched.CmdRecord` command trace against
    a declarative JEDEC (HBM4) or Table III (RoMe) rule table. The
    scheduler policies compute their own readiness clocks; every headline
    number rests on that math, so the checker re-derives legality from
    the timing dataclasses alone and reports per-rule violation counts.
``conformance``
    Runs every registered scheduler policy over the facade trace suite
    plus adversarial stressors and aggregates checker reports — the
    per-policy conformance census gated in CI.
``lints``
    AST-based repo-invariant lints (compat boundary, determinism,
    mutable defaults, pool picklability) behind ``scripts/lint.py``.
"""
from .conformance import conformance_report, policy_conformance
from .timing_checker import (CheckReport, HBM4TraceChecker, RoMeTraceChecker,
                             TimingProtocolError, Violation, check_sim_result,
                             checker_for_sim)

__all__ = [
    "CheckReport", "Violation", "TimingProtocolError",
    "HBM4TraceChecker", "RoMeTraceChecker",
    "checker_for_sim", "check_sim_result",
    "conformance_report", "policy_conformance",
]
