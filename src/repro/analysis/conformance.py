"""Per-policy timing-conformance sweep: every registered scheduling
point replayed through the independent :mod:`.timing_checker`.

Each policy runs (a) every facade-suite transaction trace of its family
— the same 20-trace contract the scalar/vectorized bit-identity check
uses — and (b) a set of adversarial stressors built to poke the rules a
well-behaved stream never exercises: mixed read/write bank thrash
(turnarounds + PRE/ACT churn), row-miss ACT pressure (tFAW/tRRD), cross-
SID interleave (tCCDR), write-batch turnaround flips (tRTW/tWTR), sparse
arrivals across many refresh periods (bounded postponement), and
same-VBA chaining for RoMe (tRD_row/tWR_row).

Everything is seeded and deterministic, so the aggregate census is
byte-stable and gated as ``benchmarks/baselines/timing_conformance.json``.
"""
from __future__ import annotations

import numpy as np

from ..core.sched import Txn, registered_policies
from ..core.sched.registry import PolicySpec, policy_spec
from ..core.sched.traces import facade_trace_suite
from .timing_checker import CheckReport, check_sim_result

#: Fast subset for the per-commit CI sanitizer pass: one spec per
#: distinct sim kind (the queue-depth / refresh-knob variants share
#:  their scheduling code with rome_qd2).
REDUCED_POLICIES = ("hbm4_frfcfs", "hbm4_closed", "hbm4_writedrain",
                    "hbm4_sidgroup", "rome_qd2")


def _hbm4_stressors(n: int):
    rng = np.random.default_rng(8)
    out = []

    txns = [Txn(i * 0.5, int(rng.integers(0, 128)), int(rng.integers(0, 8)),
                col=int(rng.integers(0, 32)),
                is_write=bool(rng.integers(0, 2)),
                sid=int(rng.integers(0, 4)))
            for i in range(n)]
    out.append(("stress_rw_thrash", txns))

    # Row-miss ACT pressure inside one PC: every access opens a new row
    # on a rotating 4-bank set, so ACT spacing and the rolling tFAW
    # window are the binding constraints.
    txns = [Txn(i * 0.25, (i * 4) % 64, i, col=0, is_write=False)
            for i in range(n)]
    out.append(("stress_act_pressure", txns))

    # Cross-SID interleave on shared banks (tCCDR + SID grouping).
    txns = [Txn(i * 0.5, int(rng.integers(0, 64)), int(rng.integers(0, 4)),
                col=int(rng.integers(0, 32)),
                is_write=bool(rng.integers(0, 4) == 0), sid=i % 4)
            for i in range(n)]
    out.append(("stress_xsid_mix", txns))

    # Write batches flipping to read batches on open rows: bus
    # turnarounds (tRTW, tWTRS/tWTRL) at maximum rate.
    txns = []
    for batch in range(max(2, n // 32)):
        wr = batch % 2 == 0
        for j in range(32):
            bank = (batch + j) % 8
            txns.append(Txn(batch * 8.0, bank, 0, col=j % 32, is_write=wr))
    out.append(("stress_turnaround", txns))

    # Sparse arrivals over ~40 refresh periods: refresh issues must ride
    # in the gaps with bounded postponement.
    txns = [Txn(i * 600.0, int(rng.integers(0, 128)),
                int(rng.integers(0, 8)), col=int(rng.integers(0, 32)),
                is_write=bool(rng.integers(0, 2)))
            for i in range(max(8, n // 75))]
    out.append(("stress_sparse_refresh", txns))
    return out


def _rome_stressors(n: int):
    rng = np.random.default_rng(9)
    out = []

    txns = [Txn(i * 10.0, int(rng.integers(0, 16)), int(rng.integers(0, 64)),
                is_write=bool(rng.integers(0, 2)),
                sid=int(rng.integers(0, 4)))
            for i in range(n)]
    out.append(("stress_rome_rw_mix", txns))

    # Same-VBA chaining: every command must wait the full service time.
    txns = [Txn(i * 10.0, 0, i, is_write=bool(i % 3 == 0))
            for i in range(n)]
    out.append(("stress_rome_vba_chain", txns))

    # Strict SID round-robin (tR2RR/tW2WR cross-SID gaps).
    txns = [Txn(i * 10.0, i % 16, i, is_write=bool(i % 2), sid=i % 4)
            for i in range(n)]
    out.append(("stress_rome_xsid", txns))

    # Sparse arrivals across many VBA-paired refresh periods.
    txns = [Txn(i * 900.0, int(rng.integers(0, 16)),
                int(rng.integers(0, 64)), is_write=bool(rng.integers(0, 2)))
            for i in range(max(8, n // 12))]
    out.append(("stress_rome_sparse_refresh", txns))
    return out


def _traces_for(spec: PolicySpec, reduced: bool):
    """(label, txns) pairs: facade-suite traces of the spec's family plus
    the family's adversarial stressors. Transactions are rebuilt per call
    — the sims take ownership of arrival ordering."""
    fam = spec.family
    out = [(label, txns) for label, kind, _, txns in facade_trace_suite()
           if ("rome" if kind == "rome" else "hbm4") == fam]
    if reduced:
        out = out[::2]
    n = 200 if reduced else 600
    out.extend(_hbm4_stressors(n) if fam == "hbm4" else _rome_stressors(n))
    return out


def policy_conformance(name_or_spec, reduced: bool = False) -> dict:
    """Conformance census for one registered policy."""
    spec = (name_or_spec if isinstance(name_or_spec, PolicySpec)
            else policy_spec(name_or_spec))
    agg = CheckReport(spec.name)
    per_trace_bad = {}
    n_traces = 0
    for label, txns in _traces_for(spec, reduced):
        sim = spec.make_sim(emit_trace=True)
        rep = check_sim_result(sim, sim.run(txns), f"{spec.name}:{label}")
        agg.merge(rep)
        n_traces += 1
        if not rep.ok:
            per_trace_bad[label] = dict(sorted(rep.counts.items()))
    res = {
        "policy": spec.name,
        "family": spec.family,
        "n_traces": n_traces,
        "n_commands": agg.n_commands,
        "violations": dict(sorted(agg.counts.items())),
        "total_violations": sum(agg.counts.values()),
        "clean": agg.ok,
    }
    if per_trace_bad:
        res["bad_traces"] = per_trace_bad
        res["examples"] = [f"{v.rule}@{v.t_ns:.3f} bank {v.bank}: {v.detail}"
                           for v in agg.violations[:10]]
    return res


def conformance_report(policies=None, reduced: bool = False) -> dict:
    """Census over all (or the given) registered policies."""
    names = tuple(policies) if policies is not None else \
        (REDUCED_POLICIES if reduced else tuple(registered_policies()))
    per = {name: policy_conformance(name, reduced=reduced) for name in names}
    return {
        "reduced": reduced,
        "policies": per,
        "n_policies": len(per),
        "n_commands": sum(p["n_commands"] for p in per.values()),
        "total_violations": sum(p["total_violations"] for p in per.values()),
        "clean": all(p["clean"] for p in per.values()),
    }
