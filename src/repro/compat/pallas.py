"""Pallas-TPU compatibility: compiler-params class rename.

JAX ≥ 0.6 spells the Mosaic compiler options ``pltpu.CompilerParams``;
0.4.x–0.5.x spell it ``pltpu.TPUCompilerParams``. Same constructor surface
for the options this repo uses (``dimension_semantics``).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(*, dimension_semantics: tuple | None = None, **kw):
    """Build the installed JAX's Mosaic compiler-params object."""
    if dimension_semantics is not None:
        kw["dimension_semantics"] = dimension_semantics
    return _PARAMS_CLS(**kw)
