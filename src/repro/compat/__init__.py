"""JAX version-compatibility layer.

Every JAX API whose surface drifted across the versions this repo supports
(0.4.35 – 0.6.x) is adapted exactly once, here, by feature detection at
import time — source modules import the stable names below and never touch
the drifting spellings directly.

Policy (documented in CHANGES.md): when an API moves, add the adapter here
with a feature probe (``hasattr`` / ``TypeError`` fallback, never a version
string compare), keep the *new* JAX spelling as the canonical argument
surface, and cover both branches in tests where the installed JAX allows.

Stable surface:
  * :func:`tpu_compiler_params`      — pltpu.CompilerParams / TPUCompilerParams
  * :func:`make_mesh`                — jax.make_mesh with/without axis_types
  * :func:`set_mesh`                 — jax.set_mesh / sharding.use_mesh / Mesh ctx
  * :func:`active_mesh_axis_names`   — abstract mesh / thread-resource env
  * :func:`mesh_axis_sizes`          — Mesh.axis_sizes / devices.shape
  * :func:`shard_map`                — jax.shard_map / experimental.shard_map
  * :func:`normalize_cost_analysis`  — dict vs list[dict] returns
  * :func:`xla_cost_analysis`        — Compiled -> normalized flat dict
  * :func:`tree_map`                 — jax.tree.map / jax.tree_util.tree_map
"""
from __future__ import annotations

from .hlo import normalize_cost_analysis, xla_cost_analysis
from .pallas import tpu_compiler_params
from .sharding import (active_mesh, active_mesh_axis_names, make_mesh,
                       mesh_axis_sizes, set_mesh, shard_map)
from .tree import tree_map

__all__ = [
    "tpu_compiler_params",
    "make_mesh",
    "set_mesh",
    "active_mesh",
    "active_mesh_axis_names",
    "mesh_axis_sizes",
    "shard_map",
    "normalize_cost_analysis",
    "xla_cost_analysis",
    "tree_map",
]
