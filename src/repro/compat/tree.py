"""Pytree compatibility: ``jax.tree.map`` appeared in 0.4.25; older JAX
only has ``jax.tree_util.tree_map`` (same semantics incl. ``is_leaf``)."""
from __future__ import annotations

import jax

if hasattr(jax, "tree") and hasattr(jax.tree, "map"):
    tree_map = jax.tree.map
else:                                         # pragma: no cover — old JAX
    tree_map = jax.tree_util.tree_map
