"""Mesh / sharding compatibility.

Three drifts are adapted:

* ``jax.make_mesh`` grew an ``axis_types=`` kwarg (with
  ``jax.sharding.AxisType``) in 0.5.x; 0.4.x takes only (shapes, names).
* ``jax.set_mesh`` (0.6) / ``jax.sharding.use_mesh`` (0.5) install the
  *abstract* mesh that ``with_sharding_constraint(PartitionSpec)`` reads at
  trace time; on 0.4.x the equivalent is the classic ``with mesh:``
  thread-resource context.
* the active-mesh query is ``jax.sharding.get_abstract_mesh()`` on new JAX;
  on 0.4.x it is the physical mesh of the thread-resource env.
* ``shard_map`` was promoted to ``jax.shard_map`` in 0.5.x; on 0.4.x it
  lives in ``jax.experimental.shard_map`` (found by the lint pass: the
  ``jax.shard_map`` spelling made the sequence-sharded KV-cache path an
  AttributeError on 0.4.x the moment a mesh was actually in scope).
"""
from __future__ import annotations

import contextlib

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with Auto axis types where the API has them."""
    if _AXIS_TYPE is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(_AXIS_TYPE.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


@contextlib.contextmanager
def set_mesh(mesh):
    """Install `mesh` as the ambient (abstract) mesh for tracing.

    Prefers ``jax.set_mesh`` / ``jax.sharding.use_mesh``; on 0.4.x falls
    back to the ``with mesh:`` resource env, which is what
    ``with_sharding_constraint`` consults there.
    """
    setter = getattr(jax, "set_mesh", None) \
        or getattr(jax.sharding, "use_mesh", None)
    cm = setter(mesh) if setter is not None else mesh
    with cm:
        yield mesh


def active_mesh():
    """The mesh in scope at trace time (abstract on new JAX, the resource
    env's physical mesh on 0.4.x); None when unmeshed."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        try:
            m = get_abstract()
        except Exception:
            m = None
        if m is not None and m.axis_names:
            return m
    try:        # 0.4.x: `with mesh:` populates the thread-resource env
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def active_mesh_axis_names() -> tuple:
    """Axis names of the mesh in scope at trace time; () when unmeshed."""
    m = active_mesh()
    return tuple(m.axis_names) if m is not None else ()


def mesh_axis_sizes(mesh) -> dict:
    """{axis name: size} for physical or abstract meshes on any version."""
    sizes = getattr(mesh, "axis_sizes", None)
    if sizes is not None:
        return dict(zip(mesh.axis_names, sizes))
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` (0.5+) / ``jax.experimental.shard_map`` (0.4.x)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
