"""Compiled-artifact compatibility: ``Compiled.cost_analysis()`` returned
``list[dict]`` (one entry per partition/program) through JAX 0.4.x and a
flat ``dict`` from 0.5.x on. Consumers here always see the flat dict.
"""
from __future__ import annotations


def normalize_cost_analysis(cost) -> dict:
    """list[dict] | dict | None -> flat {metric: value} dict."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


def xla_cost_analysis(compiled) -> dict:
    """Normalized cost analysis of a ``jax.stages.Compiled``; {} when the
    backend provides none."""
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return {}
    return normalize_cost_analysis(cost)
